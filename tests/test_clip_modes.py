"""Clipping-mode subsystem property tests.

Budgets always satisfy the sensitivity invariant (Σ C_l² = C², under
uniform / mapping / auto splits), clipped per-example gradients never
exceed their bound in flat/per_layer, noise variance stays pinned
per-dtype under every mode, stale steady state is exactly 1 forward +
1 backward with the fused ``gram_norm_fused`` path selected by the
planner on a conv model (tapper.STATS counters), metrics are labeled
per mode, and plan/mode mismatches fail loudly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import true_norms_sq
from repro.core import (ClipPolicy, DPConfig, PrivacyEngine, costmodel,
                        clipped_grad_sum_detailed, clipping_sensitivity,
                        resolve_budgets)
from repro.core.clipping import dp_gradient
from repro.core.strategies import clip_coefficients
from repro.core.tapper import STATS, Tapper

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    settings.register_profile("clip_modes", max_examples=25, deadline=None)
    settings.load_profile("clip_modes")
except ImportError:
    HAVE_HYPOTHESIS = False


def two_group_model(dtype=jnp.float32, B=4, seed=0, scale=1.0):
    """conv + dense head: two parameter groups.  The conv sits in the
    ghost (Gram) regime — small output spatial (3×3 from a 5×5 input),
    wide channels — so a stale plan fuses its norm+contrib."""
    rng = np.random.RandomState(seed)
    params = {"c": {"w": jnp.asarray(rng.randn(16, 4, 3, 3), dtype) * 0.3
                    * scale,
                    "b": jnp.asarray(rng.randn(16), dtype) * 0.1},
              "fc": {"w": jnp.asarray(rng.randn(16, 5), dtype) * 0.3}}

    def apply_fn(p, batch, tp):
        y = tp.conv("c", batch["x"], p["c"]["w"], p["c"]["b"], stride=1,
                    padding=0)
        h = jnp.tanh(y.astype(jnp.float32)).mean(axis=(2, 3))
        o = tp.dense("fc", h, p["fc"]["w"])
        return jnp.sum(o ** 2, axis=1)

    batch = {"x": jnp.asarray(rng.randn(B, 4, 5, 5), dtype)}
    return apply_fn, params, batch


def _ident_opt(grads, state, params, *, lr, weight_decay):
    return params, state


# ---------------------------------------------------------------------------
# Budget splits: Σ C_l² == C² always


@pytest.mark.parametrize("G", (1, 2, 7))
def test_uniform_budgets_sensitivity(G):
    C = 1.7
    b = resolve_budgets(ClipPolicy(mode="per_layer"), C,
                        tuple(f"g{i}" for i in range(G)))
    assert b.shape == (G,)
    np.testing.assert_allclose(clipping_sensitivity(b), C, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(b), C / np.sqrt(G), rtol=1e-6)


def test_mapping_budgets_glob_match_and_sensitivity():
    C = 0.5
    policy = ClipPolicy(mode="per_layer",
                        budgets={"blocks/*": 2.0, "head": 0.5})
    keys = ("blocks/fc", "blocks/nrm", "head", "emb")
    b = np.asarray(resolve_budgets(policy, C, keys))
    np.testing.assert_allclose(clipping_sensitivity(b), C, rtol=1e-6)
    # relative weights preserved: blocks twice head's 0.5, unmatched = 1
    np.testing.assert_allclose(b[0] / b[2], 4.0, rtol=1e-5)
    np.testing.assert_allclose(b[0] / b[3], 2.0, rtol=1e-5)


if HAVE_HYPOTHESIS:

    @given(st.lists(st.floats(1e-6, 1e6), min_size=1, max_size=12),
           st.floats(1e-3, 1e3))
    def test_auto_budgets_sensitivity_property(observed, C):
        """Any observed per-layer quantile vector yields an 'auto' split
        with Σ C_l² == C² — the accountant's sensitivity invariant."""
        policy = ClipPolicy(mode="per_layer", budgets="auto")
        keys = tuple(f"g{i}" for i in range(len(observed)))
        b = resolve_budgets(policy, C, keys, observed=np.asarray(observed))
        np.testing.assert_allclose(clipping_sensitivity(b), C, rtol=1e-5)
        assert bool(np.all(np.asarray(b) > 0))


# ---------------------------------------------------------------------------
# Clipped-gradient norm bounds (via the pipeline's own coefficients
# applied to oracle per-example grads)


def _oracle_pe(apply_fn, params, batch):
    return jax.jacrev(lambda p: apply_fn(p, batch, Tapper()))(params)


@pytest.mark.parametrize("mode", ("flat", "per_layer"))
@pytest.mark.parametrize("scale", (1.0, 4.0), ids=("mild", "hot"))
def test_clipped_grad_norm_never_exceeds_C(mode, scale):
    """Apply the pipeline's coefficients to the oracle's per-example
    grads: every example's clipped contribution has norm ≤ C (up to the
    norm realizations' float error)."""
    apply_fn, params, batch = two_group_model(scale=scale)
    C = 0.05
    _, _, _, detail = clipped_grad_sum_detailed(
        apply_fn, params, batch, l2_clip=C, strategy="auto",
        clip_policy=ClipPolicy(mode=mode))
    pe = _oracle_pe(apply_fn, params, batch)
    if mode == "flat":
        coef = {"c": detail["coef"], "fc": detail["coef"]}
    else:
        keys = detail["group_keys"]
        coef = {k: detail["coef"][i] for i, k in enumerate(keys)}
    clipped_sq = sum(
        jnp.sum((leaf.astype(jnp.float32)
                 * coef[key].reshape((-1,) + (1,) * (leaf.ndim - 1))) ** 2,
                axis=tuple(range(1, leaf.ndim)))
        for key in ("c", "fc") for leaf in jax.tree.leaves(pe[key]))
    assert bool(jnp.all(jnp.sqrt(clipped_sq) <= C * 1.001)), \
        f"max clipped norm {float(jnp.sqrt(clipped_sq).max())} > C={C}"


# ---------------------------------------------------------------------------
# Noise variance pinned per-dtype under every mode


@pytest.mark.parametrize("mode", ("flat", "per_layer", "stale"))
@pytest.mark.parametrize("dtype", (jnp.float32, jnp.bfloat16),
                         ids=("f32", "bf16"))
def test_noise_variance_pinned_under_modes(mode, dtype):
    """The σC calibration is mode-independent: the noisy and noiseless
    gradients of the same step differ by N(0, (σC/denom)²) noise in
    float32, for every clipping mode and capture dtype."""
    apply_fn, params, batch = two_group_model(dtype=dtype, B=4)
    sigma, C = 1.5, 0.1
    B = batch["x"].shape[0]
    cfg0 = DPConfig(l2_clip=C, noise_multiplier=0.0, clipping=mode)
    cfgn = DPConfig(l2_clip=C, noise_multiplier=sigma, clipping=mode)
    state = None
    if mode == "stale":
        _, _, aux = dp_gradient(apply_fn, params, batch, cfg=cfg0)
        state = aux["clip_state"]
    _, g0, _ = dp_gradient(apply_fn, params, batch, cfg=cfg0,
                           clip_state=state)
    _, gn, _ = dp_gradient(apply_fn, params, batch, cfg=cfgn,
                           key=jax.random.PRNGKey(5), clip_state=state)
    diff = np.concatenate([
        (np.asarray(a, np.float64) - np.asarray(b, np.float64)).ravel()
        for a, b in zip(jax.tree.leaves(gn), jax.tree.leaves(g0))])
    np.testing.assert_allclose(diff.std(), sigma * C / B, rtol=0.1)


# ---------------------------------------------------------------------------
# Stale steady state: 1 forward + 1 backward, fused plan (acceptance
# criterion — proven by tapper.STATS on a conv model)


def test_stale_steady_state_single_pass_fused_conv():
    apply_fn, params, batch = two_group_model()
    costmodel.clear_plan_cache()
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(l2_clip=0.1, clipping="stale"),
                           optimizer=_ident_opt)
    plan = engine.plan()
    assert plan.clip_mode == "stale"
    fused = [n for n, lp in plan.layers.items() if lp.fused]
    assert "c" in fused, "the ghost-regime conv must be fused"
    # Steady state, eagerly (STATS tick per real execution): exactly one
    # forward + one backward, with the fused kernel realizing the conv's
    # norm and contribution in one pass.
    from repro.core.strategies import clipped_grad_sum_detailed as cgs
    _, _, prev_ns, _ = cgs(apply_fn, params, batch, l2_clip=0.1,
                           strategy="auto")
    STATS.reset()
    cgs(apply_fn, params, batch, l2_clip=0.1, strategy="auto",
        clip_policy=ClipPolicy(mode="stale"), prev_norms_sq=prev_ns,
        plan=plan)
    assert STATS.snapshot() == {"forwards": 1, "backwards": 1, "probes": 0}
    assert STATS.fused >= 1


def test_stale_engine_bootstrap_then_steady():
    apply_fn, params, batch = two_group_model()
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(l2_clip=0.1, clipping="stale"),
                           optimizer=_ident_opt)
    opt0 = {"step": jnp.zeros(())}
    _, _, _, aux1 = engine.private_step(params, opt0, batch)
    assert engine._prev_norms_sq is not None
    _, _, _, aux2 = engine.private_step(params, opt0, batch)
    # same params+batch: the lagged fraction now reflects the applied
    # (previous-step) norms, which equal the current ones here
    np.testing.assert_allclose(float(aux2["clip_fraction_lagged"]),
                               float(aux2["clip_fraction"]))


# ---------------------------------------------------------------------------
# Mode-dependent metrics


def test_per_layer_metrics_shape_and_budgets():
    apply_fn, params, batch = two_group_model()
    cfg = DPConfig(l2_clip=0.1, clipping="per_layer")
    _, _, aux = dp_gradient(apply_fn, params, batch, cfg=cfg)
    assert aux["per_layer_clip_fraction"].shape == (2,)
    assert aux["per_layer_norms"].shape == (2, 4)
    np.testing.assert_allclose(
        float(jnp.sum(jnp.square(aux["clip_budgets"]))), 0.1 ** 2,
        rtol=1e-5)
    np.testing.assert_allclose(
        float(aux["clip_fraction"]),
        float(jnp.mean(aux["per_layer_clip_fraction"])), rtol=1e-6)


def test_stale_metrics_labeled_lagged():
    apply_fn, params, batch = two_group_model()
    cfg = DPConfig(l2_clip=0.1, clipping="stale")
    _, _, aux = dp_gradient(apply_fn, params, batch, cfg=cfg)  # bootstrap
    assert "clip_fraction_lagged" in aux and "clip_state" in aux
    # feed deliberately tiny previous norms: nothing was clipped by the
    # lagged coefficients even though current norms exceed C
    tiny = {"prev_norms_sq": jnp.full((4,), 1e-8)}
    _, _, aux2 = dp_gradient(apply_fn, params, batch, cfg=cfg,
                             clip_state=tiny)
    assert float(aux2["clip_fraction_lagged"]) == 0.0
    assert float(aux2["clip_fraction"]) == 1.0


# ---------------------------------------------------------------------------
# Engine auto budgets


def test_engine_auto_budgets_track_and_stay_calibrated():
    apply_fn, params, batch = two_group_model()
    policy = ClipPolicy(mode="per_layer", budgets="auto", ema=0.5)
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(l2_clip=0.1, clipping=policy),
                           optimizer=_ident_opt)
    opt0 = {"step": jnp.zeros(())}
    uniform = np.asarray(engine._clip_state()["budgets"])   # pre-step split
    np.testing.assert_allclose(uniform, 0.1 / np.sqrt(2), rtol=1e-5)
    engine.private_step(params, opt0, batch)
    adapted = np.asarray(engine._budgets)
    np.testing.assert_allclose(clipping_sensitivity(adapted), 0.1,
                               rtol=1e-4)
    # the groups' observed norm quantiles differ, so the tracked split
    # must move away from uniform while staying calibrated
    assert abs(adapted[0] - uniform[0]) > 1e-6


# ---------------------------------------------------------------------------
# Fail-loudly: plan/mode mismatches, invalid configs


def test_plan_clip_mode_mismatch_raises():
    apply_fn, params, batch = two_group_model()
    flat_plan = costmodel.get_plan(apply_fn, params, batch)
    with pytest.raises(ValueError, match="clipping mode"):
        clipped_grad_sum_detailed(
            apply_fn, params, batch, l2_clip=0.1, strategy="auto",
            clip_policy=ClipPolicy(mode="per_layer"), plan=flat_plan)
    with pytest.raises(ValueError, match="clipping mode"):
        costmodel.check_plan_matches(flat_plan, clip_mode="stale")
    with pytest.raises(ValueError, match="clipping mode"):
        PrivacyEngine(apply_fn, params, batch,
                      dp=DPConfig(l2_clip=0.1, clipping="per_layer"),
                      plan=flat_plan)


def test_clip_mode_roundtrips_through_plan_json():
    apply_fn, params, batch = two_group_model()
    plan = costmodel.get_plan(apply_fn, params, batch, clip_mode="stale")
    plan2 = costmodel.ExecPlan.from_json(plan.to_json())
    assert plan2.clip_mode == "stale"
    assert {n for n, lp in plan2.layers.items() if lp.fused} \
        == {n for n, lp in plan.layers.items() if lp.fused}
    assert plan2 == plan


def test_invalid_mode_and_strategy_combinations():
    with pytest.raises(ValueError, match="unknown clipping mode"):
        ClipPolicy(mode="lazy")
    with pytest.raises(ValueError, match="requires strategy"):
        DPConfig(clipping="per_layer", strategy="ghost")
    apply_fn, params, batch = two_group_model()
    with pytest.raises(ValueError, match="prev_norms_sq"):
        clipped_grad_sum_detailed(
            apply_fn, params, batch, l2_clip=0.1, strategy="bk",
            clip_policy=ClipPolicy(mode="stale"))


def test_per_layer_plan_never_uses_weighted_backward():
    """Under per_layer/stale the planner must not pick the shared
    weighted backward even where flat would: force the flat plan's
    backward trigger via a local_vjp-heavy model and check the non-flat
    plans keep contrib."""
    apply_fn, params, batch = two_group_model()
    for mode in ("per_layer", "stale"):
        plan = costmodel.get_plan(apply_fn, params, batch, clip_mode=mode)
        assert not plan.needs_backward
        assert all(g.sum_method != "backward" for g in plan.groups)


# ---------------------------------------------------------------------------
# Microbatching interacts with every mode


@pytest.mark.parametrize("mode", ("per_layer", "stale"))
def test_microbatch_equivalence_under_modes(mode):
    apply_fn, params, batch = two_group_model()
    state = None
    if mode == "stale":
        _, _, aux = dp_gradient(
            apply_fn, params, batch,
            cfg=DPConfig(l2_clip=0.1, clipping=mode))
        state = aux["clip_state"]
    outs = []
    for m in (1, 2):
        cfg = DPConfig(l2_clip=0.1, clipping=mode, microbatches=m)
        _, g, _ = dp_gradient(apply_fn, params, batch, cfg=cfg,
                              clip_state=state)
        outs.append(g)
    diff = max(float(jnp.abs(a - b).max())
               for a, b in zip(jax.tree.leaves(outs[0]),
                               jax.tree.leaves(outs[1])))
    assert diff < 1e-6
