"""Ghost/bk exactness on the structurally hard architectures: tied
embeddings (cross term), MoE segmented experts, SSM local-VJP params,
Zamba's weight-shared attention block."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tree_maxdiff, true_norms_sq
from repro.configs import get_config
from repro.core import clipped_grad_sum, ghost_norms, per_example_grads
from repro.models.registry import build_model

ARCHS = ["olmo-1b", "granite-moe-1b-a400m", "xlstm-125m", "zamba2-2.7b",
         "deepseek-v3-671b"]
B, T = 3, 8


def _setup(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    batch = {"tokens": jnp.array(rng.randint(0, cfg.vocab, (B, T))),
             "labels": jnp.array(rng.randint(0, cfg.vocab, (B, T)))}
    return model, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_ghost_norms_exact(arch):
    model, params, batch = _setup(arch)
    _, pe = per_example_grads(model.apply, params, batch, "naive")
    want = true_norms_sq(pe)
    _, got, _ = ghost_norms(model.apply, params, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("strategy", ["ghost", "bk"])
def test_clipped_grads_exact(arch, strategy):
    model, params, batch = _setup(arch)
    _, ref, nref = clipped_grad_sum(model.apply, params, batch, l2_clip=1.0,
                                    strategy="naive")
    _, got, _ = clipped_grad_sum(model.apply, params, batch, l2_clip=1.0,
                                 strategy=strategy)
    scale = max(float(jnp.abs(x).max()) for x in jax.tree.leaves(ref))
    assert tree_maxdiff(got, ref) < 5e-5 * max(scale, 1.0)
