"""Data pipeline: determinism, Poisson subsampling, prefetch resume."""
import numpy as np

from repro.data import (PrefetchLoader, SyntheticImageDataset,
                        SyntheticLMDataset, poisson_batch_indices,
                        shard_for_host)


def test_lm_determinism():
    a = SyntheticLMDataset(100, 16, seed=3)
    b = SyntheticLMDataset(100, 16, seed=3)
    np.testing.assert_array_equal(a.example(5)["tokens"],
                                  b.example(5)["tokens"])
    assert not np.array_equal(a.example(5)["tokens"],
                              a.example(6)["tokens"])


def test_lm_labels_shifted():
    ex = SyntheticLMDataset(50, 8).example(0)
    np.testing.assert_array_equal(ex["tokens"][1:], ex["labels"][:-1])


def test_image_classes_distinct():
    ds = SyntheticImageDataset(8, 4)
    ex = ds.example(0)
    assert ex["img"].shape == (3, 8, 8)
    assert 0 <= int(ex["label"]) < 4


def test_poisson_reproducible():
    i1, m1 = poisson_batch_indices(9, 1000, 0.05, 64, seed=1)
    i2, m2 = poisson_batch_indices(9, 1000, 0.05, 64, seed=1)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(m1, m2)
    i3, _ = poisson_batch_indices(10, 1000, 0.05, 64, seed=1)
    assert not np.array_equal(i1, i3)


def test_poisson_rate():
    sizes = [poisson_batch_indices(s, 10000, 0.01, 500)[1].sum()
             for s in range(30)]
    assert 60 < np.mean(sizes) < 140  # ~100 expected


def test_shard_for_host():
    idx = np.arange(12)
    parts = [shard_for_host(idx, h, 3) for h in range(3)]
    assert sorted(np.concatenate(parts).tolist()) == idx.tolist()


def test_prefetch_resume():
    ds = SyntheticLMDataset(100, 8)

    def batch_fn(step):
        return ds.batch([step, step + 1])

    l1 = PrefetchLoader(batch_fn, start_step=0)
    s0, b0 = next(l1)
    s1, b1 = next(l1)
    l1.close()
    l2 = PrefetchLoader(batch_fn, start_step=1)
    s1b, b1b = next(l2)
    l2.close()
    assert (s0, s1, s1b) == (0, 1, 1)
    np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])
