"""Pallas kernels (interpret mode) vs pure-jnp oracles, sweeping shapes
and dtypes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attn import flash_attention
from repro.kernels.gram_norm import (gram_norm, gram_norm_fused,
                                     gram_norm_tokmask)
from repro.kernels.pe_conv_grad import pe_conv_grad_1d, pe_conv_grad_2d


@pytest.mark.parametrize("shape", [(3, 50, 16, 24), (2, 256, 32, 8),
                                   (2, 300, 7, 5), (1, 8, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("has_bias", [False, True])
def test_gram_norm(shape, dtype, has_bias):
    B, T, Di, Do = shape
    rng = np.random.RandomState(sum(shape))
    x = jnp.array(rng.randn(B, T, Di), dtype)
    dy = jnp.array(rng.randn(B, T, Do), dtype)
    got = gram_norm(x, dy, has_bias=has_bias, bt=64, interpret=True)
    want = ref.gram_norm_ref(x, dy, has_bias=has_bias)
    rtol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol)


@pytest.mark.parametrize("shape", [(3, 50, 16, 24), (2, 130, 7, 5),
                                   (1, 8, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("has_bias", [False, True])
def test_gram_norm_fused_kernel_vs_ref(shape, dtype, has_bias):
    """The fused norm+contrib kernel body (interpret mode) against the
    jnp reference that serves as the CPU dispatch of ops.gram_norm_fused
    — both outputs, plus the bias contribution when present."""
    B, T, Di, Do = shape
    rng = np.random.RandomState(sum(shape))
    x = jnp.array(rng.randn(B, T, Di), dtype)
    dy = jnp.array(rng.randn(B, T, Do), dtype)
    w = jnp.array(rng.rand(B), jnp.float32)
    n_k, c_k, cb_k = gram_norm_fused(x, dy, w, has_bias=has_bias, bt=64,
                                     interpret=True)
    n_r, c_r, cb_r = ref.gram_norm_fused_ref(x, dy, w, has_bias=has_bias)
    rtol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(n_k), np.asarray(n_r), rtol=rtol)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=rtol,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(cb_k), np.asarray(cb_r),
                               rtol=rtol, atol=1e-5)


@pytest.mark.parametrize("bt", [8, 16, 64])
def test_gram_norm_tokmask(bt):
    rng = np.random.RandomState(bt)
    ids = jnp.array(rng.randint(0, 7, (2, 33)))
    dy = jnp.array(rng.randn(2, 33, 9), jnp.float32)
    got = gram_norm_tokmask(ids, dy, bt=bt, interpret=True)
    want = ref.gram_norm_tokmask_ref(ids, dy)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


@pytest.mark.parametrize("shape", [(2, 5, 6, 20, 3), (1, 3, 8, 33, 5),
                                   (4, 2, 2, 9, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pe_conv_grad_1d_kernel(shape, dtype):
    B, C, D, T, K = shape
    rng = np.random.RandomState(sum(shape))
    x = jnp.array(rng.randn(B, C, T), dtype)
    dy = jnp.array(rng.randn(B, D, T - K + 1), dtype)
    got = pe_conv_grad_1d(x, dy, K=K, interpret=True)
    want = ref.pe_conv_grad_1d_ref(x, dy, K)
    rtol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol,
                               atol=1e-2)


@pytest.mark.parametrize("shape", [(2, 3, 4, 10, 3), (1, 2, 6, 8, 2)])
def test_pe_conv_grad_2d_kernel(shape):
    B, C, D, HW, K = shape
    rng = np.random.RandomState(sum(shape))
    x = jnp.array(rng.randn(B, C, HW, HW), jnp.float32)
    dy = jnp.array(rng.randn(B, D, HW - K + 1, HW - K + 1), jnp.float32)
    got = pe_conv_grad_2d(x, dy, KH=K, KW=K, interpret=True)
    want = ref.pe_conv_grad_2d_ref(x, dy, K, K)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("cfg", [
    # (B, T, S, H, Hkv, hd, causal, bq, bk)
    (2, 64, 64, 4, 2, 16, True, 32, 32),
    (1, 128, 128, 2, 2, 8, True, 64, 32),
    (2, 32, 32, 4, 1, 16, False, 16, 16),
])
def test_flash_attention(cfg):
    B, T, S, H, Hkv, hd, causal, bq, bk = cfg
    rng = np.random.RandomState(sum(cfg))
    q = jnp.array(rng.randn(B, T, H, hd), jnp.float32)
    k = jnp.array(rng.randn(B, S, Hkv, hd), jnp.float32)
    v = jnp.array(rng.randn(B, S, Hkv, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_gram_norm_used_by_ghost(toy_model):
    """ops.gram_norm plugs into the same math the ghost strategy uses."""
    from repro.kernels import ops
    rng = np.random.RandomState(0)
    x = jnp.array(rng.randn(3, 24, 10), jnp.float32)
    dy = jnp.array(rng.randn(3, 24, 6), jnp.float32)
    got = ops.gram_norm(x, dy)
    pe = jnp.einsum("bti,bto->bio", x, dy)
    want = jnp.sum(pe ** 2, axis=(1, 2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)
