"""Kill-and-resume equivalence: the differential proof of preemption-safe
DP training.

A DP run that restarts sloppily is a *privacy* bug, not just a training
bug: replayed noise draws, a double-counted accountant ledger, or a
stale-clip bootstrap re-run with the wrong coefficients all change the
(ε, δ) guarantee silently.  The contract under test: with a
deterministic noise stream (``fold_in(PRNGKey(run_seed), step)``) and a
checkpointed :class:`DPTrainState` (params, optimizer, cross-step clip
state, ledger, plan fingerprint), a run killed at *any* step — including
mid-checkpoint-write and during the stale-clip bootstrap — resumes to
bit-identical params, optimizer state, noise draws, and ledger versus a
run that never died.  The ``multidevice`` lane proves the same for the
sharded step, and the elastic lane proves a shrunken mesh re-plans and
continues the ledger without a gap.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, CheckpointCorrupt, DPTrainState
from repro.core import (ClipPolicy, DPConfig, PrivacyAccountant,
                        PrivacyEngine, costmodel)
from repro.optim import adamw_init
from repro.runtime import (ChaosMonkey, WorkerFailure, elastic_mesh_axes,
                           run_with_restarts)

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

RUN_SEED = 7
NOISE = 0.9
STEPS = 5


class KillSignal(Exception):
    """A process death: deliberately NOT in run_with_restarts' catch set,
    so it unwinds the whole 'process' like a preemption would."""


def _bitwise_equal(a, b):
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _batch_fn(batch):
    """Deterministic per-step batch stream (pure function of step, like a
    seeded data loader): restart replay must see identical data."""
    def fn(step):
        return jax.tree.map(lambda a: jnp.roll(a, step, axis=0), batch)
    return fn


def _engine(toy, clip_mode="flat", mesh=None, batch=None):
    apply_fn, params, batch0 = toy
    clip = (ClipPolicy(mode="per_layer", budgets="auto")
            if clip_mode == "per_layer_auto" else ClipPolicy(mode=clip_mode))
    dp = DPConfig(l2_clip=0.1, noise_multiplier=NOISE, clipping=clip)
    acct = PrivacyAccountant(sampling_rate=1 / 128, noise_multiplier=NOISE)
    return PrivacyEngine(apply_fn, params,
                         batch0 if batch is None else batch, dp=dp,
                         lr=1e-2, accountant=acct, run_seed=RUN_SEED,
                         mesh=mesh)


def _drive(engine, params0, batch_fn, steps=STEPS, ckpt=None, kill_at=None,
           chaos=None, ckpt_every=1):
    """One process lifetime: restore DPTrainState if a checkpoint exists,
    then step to ``steps`` on the deterministic noise stream, dying with
    KillSignal just before executing ``kill_at``."""
    params, opt, start = params0, adamw_init(params0), 0
    if ckpt is not None and ckpt.latest_step() is not None:
        st, at = ckpt.restore_state(params, opt)
        params, opt = st.params, st.opt
        engine.load_clip_state(st.clip_state)
        engine.accountant.load_state_dict(st.ledger)
        start = at + 1
    else:
        engine.reset_clip_state()
        engine.accountant.reset()
    for step in range(start, steps):
        if kill_at is not None and step == kill_at:
            raise KillSignal(f"killed before step {step}")
        if chaos is not None:
            chaos.maybe_fail(step)
        params, opt, _, _ = engine.private_step(params, opt, batch_fn(step),
                                                step=step)
        if ckpt is not None and (step + 1) % ckpt_every == 0:
            ckpt.save_state(step, DPTrainState(
                params=params, opt=opt,
                clip_state=engine.clip_state_dict(),
                ledger=engine.accountant.state_dict(),
                plan_fingerprint=engine.fingerprint(),
                run_seed=RUN_SEED,
                mesh_axes=costmodel.mesh_axes(engine.mesh)))
    return params, opt


# ---------------------------------------------------------------------------
# The core differential lane: killed-at-step-k == never killed, bitwise


@pytest.mark.parametrize("clip_mode,kill_at", [
    ("flat", 1),
    ("flat", 3),
    ("stale", 0),            # killed during the stale-clip bootstrap step
    ("stale", 1),            # killed right after it (lagged norms live)
    ("per_layer_auto", 2),   # killed with tracked budget quantiles live
])
def test_kill_and_resume_bit_identical(toy_model, tmp_path, clip_mode,
                                       kill_at):
    params0, batch_fn = toy_model[1], _batch_fn(toy_model[2])
    ref_engine = _engine(toy_model, clip_mode)
    ref_p, ref_o = _drive(ref_engine, params0, batch_fn)
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(KillSignal):
        _drive(_engine(toy_model, clip_mode), params0, batch_fn, ckpt=ck,
               kill_at=kill_at)
    res_engine = _engine(toy_model, clip_mode)
    got_p, got_o = _drive(res_engine, params0, batch_fn, ckpt=ck)
    assert _bitwise_equal(ref_p, got_p)
    assert _bitwise_equal(ref_o, got_o)
    # the ledger continued without a gap — replayed steps are the *same*
    # mechanism outputs, so they must not be re-counted
    assert res_engine.accountant.state_dict() == \
        ref_engine.accountant.state_dict()
    assert res_engine.accountant.steps == STEPS


def test_noise_stream_is_pure_function_of_seed_and_step(toy_model):
    e1, e2 = _engine(toy_model), _engine(toy_model)
    for step in (0, 3, 1 << 20):
        np.testing.assert_array_equal(e1.noise_key(step), e2.noise_key(step))
    assert not np.array_equal(e1.noise_key(3), e1.noise_key(4))
    # a different run seed is a different stream
    e3 = PrivacyEngine(toy_model[0], toy_model[1], toy_model[2],
                       dp=DPConfig(l2_clip=0.1), run_seed=RUN_SEED + 1)
    assert not np.array_equal(e1.noise_key(3), e3.noise_key(3))


@pytest.mark.parametrize("torn", ["payload", "pointer"])
def test_kill_mid_checkpoint_write(toy_model, tmp_path, monkeypatch, torn):
    """Die inside Checkpointer.save itself — before the atomic payload
    rename ('payload': the step directory must stay invisible) or before
    the LATEST pointer rename ('pointer': the completed directory must
    still be found).  Either way the resumed run is bit-identical."""
    params0, batch_fn = toy_model[1], _batch_fn(toy_model[2])
    ref_p, ref_o = _drive(_engine(toy_model), params0, batch_fn)
    ck = Checkpointer(str(tmp_path))
    import repro.checkpoint.checkpointer as ckpt_mod
    real_rename = os.rename

    def dying_rename(src, dst):
        if "step_000000002" in src and torn == "payload" \
                and src.endswith(".tmp"):
            raise KillSignal("killed before the payload rename")
        if torn == "pointer" and src.endswith("LATEST.tmp") \
                and open(src).read().strip() == "step_000000002":
            raise KillSignal("killed before the LATEST pointer rename")
        return real_rename(src, dst)

    monkeypatch.setattr(ckpt_mod.os, "rename", dying_rename)
    with pytest.raises(KillSignal):
        _drive(_engine(toy_model), params0, batch_fn, ckpt=ck)
    monkeypatch.undo()
    expect = 1 if torn == "payload" else 2
    assert ck.available_steps()[0] == expect
    got_p, got_o = _drive(_engine(toy_model), params0, batch_fn, ckpt=ck)
    assert _bitwise_equal(ref_p, got_p)
    assert _bitwise_equal(ref_o, got_o)


def test_resume_falls_back_past_corrupt_checkpoint(toy_model, tmp_path):
    """A torn/corrupt newest checkpoint must not strand the run: restore
    falls back to the previous keep-k step and replays forward to the
    same bits."""
    params0, batch_fn = toy_model[1], _batch_fn(toy_model[2])
    ref_p, _ = _drive(_engine(toy_model), params0, batch_fn)
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(KillSignal):
        _drive(_engine(toy_model), params0, batch_fn, ckpt=ck, kill_at=4)
    # truncate the newest checkpoint's arrays file (steps 1..3 remain)
    f = os.path.join(str(tmp_path), "step_000000003", "arrays.npz")
    data = open(f, "rb").read()
    open(f, "wb").write(data[: len(data) // 2])
    # with fallback disabled the corruption is loud...
    with pytest.raises(CheckpointCorrupt):
        ck.restore_state(params0, adamw_init(params0), fallback=False)
    # ...and with it (the default) the resumed run replays from step 2
    got_p, _ = _drive(_engine(toy_model), params0, batch_fn, ckpt=ck)
    assert _bitwise_equal(ref_p, got_p)


def test_orchestrated_chaos_run_matches_reference(toy_model, tmp_path):
    """The full fault.py orchestration: ChaosMonkey trips recoverable
    WorkerFailures, run_with_restarts re-enters the segment, the segment
    restores DPTrainState — the surviving run equals the undisturbed one
    bit for bit, and the ledger is not double-counted."""
    params0, batch_fn = toy_model[1], _batch_fn(toy_model[2])
    ref_engine = _engine(toy_model, "stale")
    ref_p, _ = _drive(ref_engine, params0, batch_fn)
    ck = Checkpointer(str(tmp_path))
    engine = _engine(toy_model, "stale")
    chaos = ChaosMonkey(fail_at_steps=[1, 3])

    def segment(restart_count):
        return _drive(engine, params0, batch_fn, ckpt=ck, chaos=chaos)

    (got_p, _), restarts = run_with_restarts(segment, max_restarts=5)
    assert restarts == 2 and chaos.tripped == 2
    assert _bitwise_equal(ref_p, got_p)
    assert engine.accountant.state_dict() == \
        ref_engine.accountant.state_dict()


def test_resume_refuses_foreign_ledger(toy_model, tmp_path):
    """A checkpoint accounted under a different mechanism (σ) must not
    graft onto this run's accountant."""
    from repro.core.privacy import LedgerMismatch
    params0, batch_fn = toy_model[1], _batch_fn(toy_model[2])
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(KillSignal):
        _drive(_engine(toy_model), params0, batch_fn, ckpt=ck, kill_at=3)
    engine = _engine(toy_model)
    engine.accountant.sigma = NOISE * 2  # simulate a changed mechanism
    with pytest.raises(LedgerMismatch, match="sigma"):
        _drive(engine, params0, batch_fn, ckpt=ck)


# ---------------------------------------------------------------------------
# Sharded lanes (the 8-device CI job)


def _batch8(batch):
    return jax.tree.map(lambda a: jnp.concatenate([a, a], axis=0), batch)


@pytest.mark.multidevice
@needs_8_devices
@pytest.mark.parametrize("kill_at", [0, 2])
def test_kill_and_resume_bit_identical_sharded(toy_model, tmp_path,
                                               kill_at):
    batch = _batch8(toy_model[2])
    params0, batch_fn = toy_model[1], _batch_fn(batch)
    mesh = jax.make_mesh((8,), ("data",))
    ref_p, ref_o = _drive(_engine(toy_model, mesh=mesh, batch=batch),
                          params0, batch_fn)
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(KillSignal):
        _drive(_engine(toy_model, mesh=mesh, batch=batch), params0,
               batch_fn, ckpt=ck, kill_at=kill_at)
    res_engine = _engine(toy_model, mesh=mesh, batch=batch)
    got_p, got_o = _drive(res_engine, params0, batch_fn, ckpt=ck)
    assert _bitwise_equal(ref_p, got_p)
    assert _bitwise_equal(ref_o, got_o)
    assert res_engine.accountant.steps == STEPS


@pytest.mark.multidevice
@needs_8_devices
def test_elastic_resume_replans_onto_smaller_mesh(toy_model, tmp_path):
    """Kill a data:8 run, 'lose' half the devices, resume on data:4: the
    fingerprint mismatch is recognized as a mesh change (not a model
    change), the plan is rebuilt for the surviving topology, and the
    ledger + noise stream continue without a gap.  Params match up to
    reduction order (bitwise is only guaranteed mesh-to-same-mesh)."""
    batch = _batch8(toy_model[2])
    params0, batch_fn = toy_model[1], _batch_fn(batch)
    mesh8 = jax.make_mesh((8,), ("data",))
    ref_engine = _engine(toy_model, mesh=mesh8, batch=batch)
    ref_p, _ = _drive(ref_engine, params0, batch_fn)
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(KillSignal):
        _drive(_engine(toy_model, mesh=mesh8, batch=batch), params0,
               batch_fn, ckpt=ck, kill_at=3)
    # the surviving-mesh computation the launcher runs
    surv = elastic_mesh_axes((("data", 8),), 4, jax.tree.leaves(batch)[0]
                             .shape[0])
    assert surv == (("data", 4),)
    mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    res_engine = _engine(toy_model, mesh=mesh4, batch=batch)
    st, _ = ck.restore_state(params0, adamw_init(params0))
    # the elastic cross-check: mismatch vanishes when re-keyed under the
    # checkpoint's mesh — so this is a resumable mesh change
    assert st.plan_fingerprint != res_engine.fingerprint()
    assert st.plan_fingerprint == res_engine.fingerprint(mesh=st.mesh_axes)
    got_p, _ = _drive(res_engine, params0, batch_fn, ckpt=ck)
    assert res_engine.accountant.steps == STEPS          # no ledger gap
    # host-side compare: the two param trees live on different meshes
    diff = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
               for a, b in zip(jax.tree.leaves(ref_p),
                               jax.tree.leaves(got_p)))
    assert diff < 1e-6
