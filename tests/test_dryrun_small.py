"""Miniature multi-device dry-run in a subprocess (8 virtual devices), so
the 512-device production path is exercised without polluting this test
process's device count."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core import DPConfig
from repro.core.clipping import dp_gradient
from repro.launch import sharding as shd
from repro.launch.dryrun import abstract_params, cache_sharding, \
    cost_analysis_dict, parse_collectives
from repro.models.registry import build_model
from repro.optim import adamw_init, adamw_update

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("llama3.2-1b").reduced().replace(dtype="bfloat16")
model = build_model(cfg)

with shd.mesh_rules(mesh):
    params_sds, axes = abstract_params(model)
    pshard = shd.param_sharding(axes, mesh, shapes_tree=params_sds)
    params_in = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_sds, pshard)
    dpc = DPConfig(l2_clip=1.0, noise_multiplier=1.0, strategy="ghost",
                   microbatches=2)

    def train_step(params, opt, batch, key):
        loss, grad, aux = dp_gradient(model.apply, params, batch, cfg=dpc,
                                      key=key)
        params, opt = adamw_update(grad, opt, params)
        return params, opt, loss

    opt_sds = jax.eval_shape(adamw_init, params_sds)
    repl = NamedSharding(mesh, P())
    opt_in = {
        "m": jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=sh), opt_sds["m"], pshard),
        "v": jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=sh), opt_sds["v"], pshard),
        "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=repl),
    }
    bspec = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    bshard = shd.batch_sharding(bspec, mesh)
    batch_in = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        bspec, bshard)
    key_in = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=repl)

    lowered = jax.jit(train_step).lower(params_in, opt_in, batch_in, key_in)
compiled = lowered.compile()
ca = cost_analysis_dict(compiled)
coll = parse_collectives(compiled.as_text())
ma = compiled.memory_analysis()
print(json.dumps({
    "flops": ca.get("flops"),
    "collective_bytes": coll["total_bytes"],
    "all_reduce_count": coll["all-reduce"]["count"],
    "temp_bytes": ma.temp_size_in_bytes,
}))
"""


@pytest.mark.slow
def test_small_multipod_dryrun(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] and rec["flops"] > 0
    assert rec["collective_bytes"] > 0        # DP grad sync must exist
    assert rec["all_reduce_count"] > 0
    assert rec["temp_bytes"] > 0
