"""Property-based tests (hypothesis) of the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import clip_coefficients, ghost_norms, per_example_grads
from repro.core.privacy import rdp_subsampled_gaussian
from repro.core.tapper import Tapper
from repro.models import convops

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _tiny_apply(params, batch, tp: Tapper):
    h = tp.dense("l1", batch["x"], params["l1"]["w"], params["l1"]["b"])
    h = jnp.tanh(h)
    h = tp.dense("l2", h, params["l2"]["w"])
    return jnp.sum(h * h, axis=-1) * batch["scale"]


def _mk(seed, B=3, D=4):
    rng = np.random.RandomState(seed)
    params = {"l1": {"w": jnp.array(rng.randn(D, 5), jnp.float32),
                     "b": jnp.array(rng.randn(5), jnp.float32)},
              "l2": {"w": jnp.array(rng.randn(5, 2), jnp.float32)}}
    batch = {"x": jnp.array(rng.randn(B, D), jnp.float32),
             "scale": jnp.ones((B,), jnp.float32)}
    return params, batch


@given(st.integers(0, 1000), st.floats(0.1, 10.0))
def test_norm_homogeneity(seed, alpha):
    """Scaling example i's loss by alpha scales only its grad norm by
    alpha (per-example isolation — the core DP prerequisite)."""
    params, batch = _mk(seed)
    _, n0, _ = ghost_norms(_tiny_apply, params, batch)
    batch2 = dict(batch)
    batch2["scale"] = batch["scale"].at[1].set(alpha)
    _, n1, _ = ghost_norms(_tiny_apply, params, batch2)
    np.testing.assert_allclose(n1[1], alpha ** 2 * n0[1], rtol=1e-3)
    np.testing.assert_allclose(n1[0], n0[0], rtol=1e-5)
    np.testing.assert_allclose(n1[2], n0[2], rtol=1e-5)


@given(st.integers(0, 1000))
def test_permutation_equivariance(seed):
    params, batch = _mk(seed, B=4)
    _, pe = per_example_grads(_tiny_apply, params, batch, "crb")
    perm = np.array([2, 0, 3, 1])
    batch_p = jax.tree.map(lambda a: a[perm], batch)
    _, pe_p = per_example_grads(_tiny_apply, params, batch_p, "crb")
    for a, b in zip(jax.tree.leaves(pe), jax.tree.leaves(pe_p)):
        np.testing.assert_allclose(np.asarray(a)[perm], np.asarray(b),
                                   rtol=2e-4, atol=1e-5)


@given(st.lists(st.floats(1e-4, 1e4), min_size=1, max_size=8),
       st.floats(0.01, 100.0))
def test_clip_coef_bound(norms_sq, C):
    c = clip_coefficients(jnp.array(norms_sq, jnp.float32), l2_clip=C)
    clipped = np.sqrt(np.array(norms_sq)) * np.asarray(c)
    assert np.all(clipped <= C * (1 + 1e-3))
    assert np.all(np.asarray(c) <= 1.0 + 1e-6)


@given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 3),
       st.integers(0, 2), st.integers(1, 2), st.integers(1, 2),
       st.integers(0, 99))
def test_conv_trick_random(B, C, D, pad, stride, dil, seed):
    K, T = 3, 14
    rng = np.random.RandomState(seed)
    x = jnp.array(rng.randn(B, C, T), jnp.float32)
    h = jnp.array(rng.randn(D, C, K), jnp.float32)
    y = convops.conv_forward(x, h, stride=stride, dilation=dil, padding=pad)
    if y.shape[-1] < 1:
        return
    dy = jnp.array(rng.randn(*y.shape), jnp.float32)
    got = convops.pe_conv_grad(x, dy, kernel_spatial=(K,), stride=stride,
                               dilation=dil, padding=pad, impl="fgc")

    def loss_b(w, xb, dyb):
        return jnp.sum(convops.conv_forward(
            xb[None], w, stride=stride, dilation=dil, padding=pad) * dyb[None])

    want = jax.vmap(lambda xb, dyb: jax.grad(loss_b)(h, xb, dyb))(x, dy)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@given(st.floats(0.5, 3.0), st.floats(0.001, 0.5))
def test_rdp_positive_and_monotone_in_order(sigma, q):
    orders = (2, 4, 8, 16)
    rdp = rdp_subsampled_gaussian(q, sigma, orders)
    assert np.all(rdp >= 0)
    assert np.all(np.diff(rdp) >= -1e-12)  # nondecreasing in alpha
