"""End-to-end behaviour: DP training decreases loss; checkpoint/restart
reproduces the uninterrupted run; serving generates deterministically."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch import train as train_mod
from repro.launch import serve as serve_mod


@pytest.mark.slow
def test_dp_training_decreases_loss(tmp_path):
    losses = train_mod.main([
        "--arch", "llama3.2-1b", "--steps", "40", "--batch", "16",
        "--seq", "64", "--lr", "1e-2", "--clip", "1.0", "--noise", "0.1",
        "--strategy", "ghost"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


@pytest.mark.slow
def test_restart_reproduces_run(tmp_path):
    """A run interrupted at step 15 and restarted from its checkpoint ends
    with the same loss as an uninterrupted run (determinism contract)."""
    common = ["--arch", "llama3.2-1b", "--steps", "24", "--batch", "4",
              "--seq", "32", "--strategy", "bk", "--ckpt-every", "8"]
    a = train_mod.main(common + ["--ckpt-dir", str(tmp_path / "a")])
    b = train_mod.main(common + ["--ckpt-dir", str(tmp_path / "b"),
                                 "--fail-at", "15"])
    assert abs(a[-1] - b[-1]) < 1e-4


@pytest.mark.slow
def test_cnn_dp_training(tmp_path):
    losses = train_mod.main([
        "--arch", "alexnet", "--steps", "25", "--batch", "8",
        "--lr", "2e-3", "--strategy", "crb"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


@pytest.mark.slow
def test_serving_runs(capsys):
    serve_mod.main(["--arch", "llama3.2-1b", "--n-requests", "4",
                    "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    out = capsys.readouterr().out
    assert "served 4 requests" in out
