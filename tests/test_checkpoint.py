"""Checkpointer: atomic roundtrip, corruption detection, keep-k, async."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, CheckpointCorrupt, DPTrainState


@pytest.fixture
def tree():
    return {"a": {"w": jnp.arange(12.0).reshape(3, 4)},
            "b": jnp.ones((5,), jnp.int32),
            "step": jnp.zeros((), jnp.int32)}


def test_roundtrip(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, tree)
    got, step = ck.restore(tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(a, b)


def test_latest_pointer_and_keep(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2


def test_corruption_detected(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    path = ck.save(1, tree)
    # corrupt the arrays file
    f = os.path.join(path, "arrays.npz")
    data = dict(np.load(f))
    key = sorted(data)[0]
    data[key] = data[key] + 1
    np.savez(f, **data)
    with pytest.raises(IOError):
        ck.restore(tree)
    got, _ = ck.restore(tree, verify=False)  # opt-out works


def test_async_save(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(7, tree)
    ck.wait()
    assert ck.latest_step() == 7


def test_restore_with_shardings(tmp_path, tree):
    """Elastic path: restore places leaves onto given shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    ck = Checkpointer(str(tmp_path))
    ck.save(0, tree)
    got, _ = ck.restore(tree, shardings=sh)
    assert all(g.sharding == NamedSharding(mesh, P())
               for g in jax.tree.leaves(got))


def test_interrupted_write_is_invisible(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree)
    # simulate a crash mid-write: a .tmp dir that never got renamed
    os.makedirs(os.path.join(tmp_path, "step_000000002.tmp"))
    assert ck.latest_step() == 1
    got, step = ck.restore(tree)
    assert step == 1


def test_corruption_raises_named_exception(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    path = ck.save(1, tree)
    f = os.path.join(path, "arrays.npz")
    data = dict(np.load(f))
    key = sorted(data)[0]
    data[key] = data[key] + 1
    np.savez(f, **data)
    with pytest.raises(CheckpointCorrupt, match="CRC"):
        ck.restore(tree)


def test_truncated_arrays_falls_back_to_previous(tmp_path, tree):
    """A torn write (truncated arrays.npz) on the newest step must not
    strand the run: fallback restore lands on the previous keep-k step."""
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, tree)
    ck.save(2, jax.tree.map(lambda x: x + 1, tree))
    f = os.path.join(tmp_path, "step_000000002", "arrays.npz")
    raw = open(f, "rb").read()
    with open(f, "wb") as fh:
        fh.write(raw[: len(raw) // 2])
    with pytest.raises(CheckpointCorrupt):
        ck.restore(tree, fallback=False)
    got, step = ck.restore(tree, fallback=True)
    assert step == 1
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(a, b)
    # every checkpoint corrupt -> the last error still surfaces
    f1 = os.path.join(tmp_path, "step_000000001", "arrays.npz")
    with open(f1, "wb") as fh:
        fh.write(b"not a zip")
    with pytest.raises(CheckpointCorrupt):
        ck.restore(tree, fallback=True)


def test_train_state_roundtrip(tmp_path, tree):
    """DPTrainState persists everything a DP resume needs: clip arrays
    restored verbatim, ledger/monitor/fingerprint via the CRC'd meta."""
    ck = Checkpointer(str(tmp_path))
    opt = {"m": jnp.zeros((3, 4)), "step": jnp.asarray(5, jnp.int32)}
    clip = {"prev_norms_sq": np.arange(4.0), "budget_q": np.float32(0.7)}
    st = DPTrainState(
        params=tree, opt=opt, clip_state=clip,
        ledger={"steps": 42, "q": 0.01, "sigma": 1.1,
                "orders": [2.0, 4.0]},
        plan_fingerprint="abc123", monitor={"ema": 0.2},
        run_seed=7, mesh_axes=(("data", 8),))
    ck.save_state(3, st)
    got, step = ck.restore_state(tree, opt)
    assert step == 3
    np.testing.assert_array_equal(got.clip_state["prev_norms_sq"],
                                  clip["prev_norms_sq"])
    np.testing.assert_array_equal(got.clip_state["budget_q"],
                                  clip["budget_q"])
    assert got.ledger == st.ledger
    assert got.plan_fingerprint == "abc123"
    assert got.monitor == {"ema": 0.2}
    assert got.run_seed == 7
    assert got.mesh_axes == (("data", 8),)
    for a, b in zip(jax.tree.leaves(got.params), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(got.opt), jax.tree.leaves(opt)):
        np.testing.assert_array_equal(a, b)


def test_corrupt_meta_detected_and_fallback(tmp_path, tree):
    """Tampered meta.json (the privacy ledger lives there) fails the
    manifest CRC; restore_state falls back to the previous step."""
    ck = Checkpointer(str(tmp_path))
    opt = {"v": jnp.zeros(2)}
    good = DPTrainState(params=tree, opt=opt,
                        ledger={"steps": 1, "q": 0.1, "sigma": 1.0,
                                "orders": [2.0]})
    ck.save_state(1, good)
    ck.save_state(2, DPTrainState(params=tree, opt=opt,
                                  ledger={"steps": 2, "q": 0.1,
                                          "sigma": 1.0, "orders": [2.0]}))
    mf = os.path.join(tmp_path, "step_000000002", "meta.json")
    meta = json.load(open(mf))
    meta["ledger"]["steps"] = 0  # an adversarial/bitrot ledger edit
    with open(mf, "w") as fh:
        json.dump(meta, fh)
    with pytest.raises(CheckpointCorrupt, match="meta"):
        ck.read_meta(2)
    with pytest.raises(CheckpointCorrupt):
        ck.restore_state(tree, opt, fallback=False)
    got, step = ck.restore_state(tree, opt, fallback=True)
    assert step == 1 and got.ledger["steps"] == 1


def test_state_async_save(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    st = DPTrainState(params=tree, opt={"v": jnp.ones(3)},
                      clip_state={"budgets": np.ones(2)}, run_seed=0)
    ck.save_state_async(4, st)
    ck.wait()
    got, step = ck.restore_state(tree, {"v": jnp.ones(3)})
    assert step == 4 and got.run_seed == 0
    np.testing.assert_array_equal(got.clip_state["budgets"], np.ones(2))
