"""Checkpointer: atomic roundtrip, corruption detection, keep-k, async."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer


@pytest.fixture
def tree():
    return {"a": {"w": jnp.arange(12.0).reshape(3, 4)},
            "b": jnp.ones((5,), jnp.int32),
            "step": jnp.zeros((), jnp.int32)}


def test_roundtrip(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, tree)
    got, step = ck.restore(tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(a, b)


def test_latest_pointer_and_keep(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2


def test_corruption_detected(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    path = ck.save(1, tree)
    # corrupt the arrays file
    f = os.path.join(path, "arrays.npz")
    data = dict(np.load(f))
    key = sorted(data)[0]
    data[key] = data[key] + 1
    np.savez(f, **data)
    with pytest.raises(IOError):
        ck.restore(tree)
    got, _ = ck.restore(tree, verify=False)  # opt-out works


def test_async_save(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(7, tree)
    ck.wait()
    assert ck.latest_step() == 7


def test_restore_with_shardings(tmp_path, tree):
    """Elastic path: restore places leaves onto given shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    ck = Checkpointer(str(tmp_path))
    ck.save(0, tree)
    got, _ = ck.restore(tree, shardings=sh)
    assert all(g.sharding == NamedSharding(mesh, P())
               for g in jax.tree.leaves(got))


def test_interrupted_write_is_invisible(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree)
    # simulate a crash mid-write: a .tmp dir that never got renamed
    os.makedirs(os.path.join(tmp_path, "step_000000002.tmp"))
    assert ck.latest_step() == 1
    got, step = ck.restore(tree)
    assert step == 1
