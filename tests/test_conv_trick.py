"""The paper's Algorithm 2 (per-example conv gradients) against a
brute-force oracle and against autodiff, across stride / dilation /
padding / groups and both XLA lowerings."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import convops

CASES = [
    # (B, C, D, T, K, stride, dilation, padding, groups)
    (3, 4, 6, 16, 3, 1, 1, 0, 1),
    (2, 4, 6, 17, 5, 2, 1, 2, 1),
    (2, 4, 6, 19, 3, 1, 2, 1, 1),
    (2, 6, 9, 16, 3, 2, 2, 2, 3),
    (4, 8, 8, 21, 4, 3, 2, 3, 4),
    (1, 2, 2, 8, 2, 1, 1, 1, 2),
]


def oracle_1d(x, dy, K, s, r, p, g):
    B, C, T = x.shape
    _, D, Tp = dy.shape
    xp = np.pad(np.asarray(x), ((0, 0), (0, 0), (p, p)))
    Cg, Dg = C // g, D // g
    out = np.zeros((B, D, Cg, K))
    for b in range(B):
        for d in range(D):
            grp = d // Dg
            for c in range(Cg):
                for k in range(K):
                    acc = 0.0
                    for t in range(Tp):
                        idx = s * t + r * k
                        if idx < xp.shape[2]:
                            acc += xp[b, grp * Cg + c, idx] * dy[b, d, t]
                    out[b, d, c, k] = acc
    return out


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("impl", ["fgc", "bgc"])
def test_pe_conv_grad_1d(case, impl):
    B, C, D, T, K, s, r, p, g = case
    rng = np.random.RandomState(sum(case))
    x = jnp.array(rng.randn(B, C, T), jnp.float32)
    h = jnp.array(rng.randn(D, C // g, K), jnp.float32)
    y = convops.conv_forward(x, h, stride=s, dilation=r, padding=p, groups=g)
    dy = jnp.array(rng.randn(*y.shape), jnp.float32)
    got = convops.pe_conv_grad(x, dy, kernel_spatial=(K,), stride=s,
                               dilation=r, padding=p, groups=g, impl=impl)
    want = oracle_1d(x, dy, K, s, r, p, g)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)

    # summed over batch == autodiff weight gradient
    def loss(w):
        return jnp.sum(convops.conv_forward(x, w, stride=s, dilation=r,
                                            padding=p, groups=g) * dy)

    g_auto = jax.grad(loss)(h)
    np.testing.assert_allclose(np.asarray(got).sum(0), np.asarray(g_auto),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("impl", ["fgc", "bgc"])
@pytest.mark.parametrize("case2d", [
    (2, 3, 5, 10, 3, 1, 1, 1, 1),
    (2, 4, 4, 12, 3, 2, 1, 1, 2),
    (1, 2, 6, 9, 2, 1, 2, 0, 1),
])
def test_pe_conv_grad_2d(case2d, impl):
    B, C, D, HW, K, s, r, p, g = case2d
    rng = np.random.RandomState(sum(case2d))
    x = jnp.array(rng.randn(B, C, HW, HW), jnp.float32)
    h = jnp.array(rng.randn(D, C // g, K, K), jnp.float32)
    y = convops.conv_forward(x, h, stride=s, dilation=r, padding=p, groups=g)
    dy = jnp.array(rng.randn(*y.shape), jnp.float32)
    got = convops.pe_conv_grad(x, dy, kernel_spatial=(K, K), stride=s,
                               dilation=r, padding=p, groups=g, impl=impl)

    def loss_b(w, xb, dyb):
        return jnp.sum(convops.conv_forward(xb[None], w, stride=s,
                                            dilation=r, padding=p,
                                            groups=g) * dyb[None])

    want = jax.vmap(lambda xb, dyb: jax.grad(loss_b)(h, xb, dyb))(x, dy)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
