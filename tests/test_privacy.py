"""RDP accountant sanity + closed-form checks."""
import math

import numpy as np
import pytest

from repro.core.privacy import (DEFAULT_ORDERS, PrivacyAccountant,
                                eps_from_rdp, rdp_subsampled_gaussian)


def test_full_batch_closed_form():
    """q=1: RDP(alpha) = alpha / (2 sigma^2) exactly."""
    sigma = 1.3
    rdp = rdp_subsampled_gaussian(1.0, sigma, orders=(2, 4, 8))
    np.testing.assert_allclose(rdp, [a / (2 * sigma ** 2) for a in (2, 4, 8)])


def test_eps_monotone_in_steps():
    acct = PrivacyAccountant(sampling_rate=0.01, noise_multiplier=1.1)
    es = []
    for _ in range(3):
        acct.step(500)
        es.append(acct.epsilon(1e-5))
    assert es[0] < es[1] < es[2]


def test_eps_decreasing_in_sigma():
    out = []
    for sigma in (0.8, 1.2, 2.0):
        a = PrivacyAccountant(0.01, sigma)
        a.step(1000)
        out.append(a.epsilon(1e-5))
    assert out[0] > out[1] > out[2]


def test_eps_increasing_in_q():
    out = []
    for q in (0.001, 0.01, 0.1):
        a = PrivacyAccountant(q, 1.1)
        a.step(1000)
        out.append(a.epsilon(1e-5))
    assert out[0] < out[1] < out[2]


def test_reference_regime():
    """Abadi-style regime: q=0.01, sigma=1.0 should give single-digit eps
    after ~1e4 steps at delta=1e-5 (ballpark from the DP-SGD literature)."""
    a = PrivacyAccountant(0.01, 1.0)
    a.step(10000)
    eps = a.epsilon(1e-5)
    assert 1.0 < eps < 10.0


def test_zero_noise_is_infinite():
    a = PrivacyAccountant(0.01, 0.0)
    a.step(1)
    assert math.isinf(a.epsilon())


def test_subsampling_amplifies():
    """RDP with q<1 must be (much) smaller than unsampled at same sigma."""
    full = rdp_subsampled_gaussian(1.0, 1.0, orders=(8,))[0]
    sub = rdp_subsampled_gaussian(0.01, 1.0, orders=(8,))[0]
    assert sub < full / 10
