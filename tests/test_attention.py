"""Attention correctness: decode==train incrementally, sliding window,
MLA absorbed decode, chunked long-context path, MoE dispatch impls."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.tapper import Tapper
from repro.models import attention as attn
from repro.models import common as cm


def _gqa_params(key, D, H, KV, hd, qk_norm=False):
    tree = attn.gqa_init(key, D, H, KV, hd, qk_norm=qk_norm)
    return cm.split_tree(tree)[0]


def test_decode_matches_full_forward():
    D, H, KV, hd, B, T = 16, 4, 2, 8, 2, 10
    p = _gqa_params(jax.random.PRNGKey(0), D, H, KV, hd)
    rng = np.random.RandomState(0)
    x = jnp.array(rng.randn(B, T, D), jnp.float32)
    tp = Tapper()
    full, _ = attn.gqa_apply(tp, "a", p, x, n_heads=H, n_kv=KV, head_dim=hd,
                             causal=True)
    cache = attn.gqa_cache(B, T, KV, hd)
    outs = []
    for t in range(T):
        o, cache = attn.gqa_apply(tp, "a", p, x[:, t:t + 1], n_heads=H,
                                  n_kv=KV, head_dim=hd, cache=cache)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


def test_prefill_then_decode_matches_full():
    D, H, KV, hd, B, T = 16, 4, 4, 8, 2, 8
    p = _gqa_params(jax.random.PRNGKey(1), D, H, KV, hd)
    rng = np.random.RandomState(1)
    x = jnp.array(rng.randn(B, T, D), jnp.float32)
    tp = Tapper()
    full, _ = attn.gqa_apply(tp, "a", p, x, n_heads=H, n_kv=KV, head_dim=hd,
                             causal=True)
    cache = attn.gqa_cache(B, T, KV, hd)
    pre, cache = attn.gqa_apply(tp, "a", p, x[:, :5], n_heads=H, n_kv=KV,
                                head_dim=hd, cache=cache)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :5]),
                               rtol=2e-4, atol=2e-5)
    o5, cache = attn.gqa_apply(tp, "a", p, x[:, 5:6], n_heads=H, n_kv=KV,
                               head_dim=hd, cache=cache)
    np.testing.assert_allclose(np.asarray(o5[:, 0]), np.asarray(full[:, 5]),
                               rtol=2e-4, atol=2e-5)


def test_sliding_window_ring_cache():
    """Ring-buffer decode == full attention restricted to the window."""
    D, H, KV, hd, B, T, W = 16, 2, 2, 8, 1, 12, 4
    p = _gqa_params(jax.random.PRNGKey(2), D, H, KV, hd)
    rng = np.random.RandomState(2)
    x = jnp.array(rng.randn(B, T, D), jnp.float32)
    tp = Tapper()
    full, _ = attn.gqa_apply(tp, "a", p, x, n_heads=H, n_kv=KV, head_dim=hd,
                             causal=True, window=W)
    cache = attn.gqa_cache(B, W, KV, hd)  # ring size == window
    outs = []
    for t in range(T):
        o, cache = attn.gqa_apply(tp, "a", p, x[:, t:t + 1], n_heads=H,
                                  n_kv=KV, head_dim=hd, cache=cache,
                                  window=W)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


def test_chunked_equals_full():
    D, H, KV, hd, B, T = 16, 2, 2, 8, 2, 64
    rng = np.random.RandomState(3)
    q = jnp.array(rng.randn(B, T, H, hd), jnp.float32)
    k = jnp.array(rng.randn(B, T, H, hd), jnp.float32)
    v = jnp.array(rng.randn(B, T, H, hd), jnp.float32)
    full = attn.attend(q, k, v, causal=True, impl="xla")
    chunked = attn.sdpa_chunked(q, k, v, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("absorbed", [False, True])
def test_mla_decode_matches_train(absorbed):
    D, H = 24, 2
    kw = dict(n_heads=H, q_lora_rank=8, kv_lora_rank=12, qk_nope_dim=6,
              qk_rope_dim=4, v_head_dim=6)
    tree = attn.mla_init(jax.random.PRNGKey(4), D, H, q_lora_rank=8,
                         kv_lora_rank=12, qk_nope_dim=6, qk_rope_dim=4,
                         v_head_dim=6)
    p = cm.split_tree(tree)[0]
    rng = np.random.RandomState(4)
    B, T = 2, 7
    x = jnp.array(rng.randn(B, T, D), jnp.float32)
    tp = Tapper()
    full, _ = attn.mla_apply(tp, "m", p, x, **kw)
    cache = attn.mla_cache(B, T, 12, 4)
    outs = []
    for t in range(T):
        o, cache = attn.mla_apply(tp, "m", p, x[:, t:t + 1], cache=cache,
                                  absorbed_decode=absorbed, **kw)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=3e-4, atol=3e-5)


def test_moe_einsum_vs_gather():
    """Both dispatch impls compute the same MoE layer output with ample
    capacity (routing identical; only the slot bookkeeping differs)."""
    from repro.models.moe import moe_apply, moe_init
    D, F, E, K = 16, 24, 4, 2
    tree = moe_init(jax.random.PRNGKey(5), D, F, E)
    p = cm.split_tree(tree)[0]
    rng = np.random.RandomState(5)
    x = jnp.array(rng.randn(2, 6, D), jnp.float32)
    tp = Tapper()
    y1, lb1 = moe_apply(tp, "moe", p, x, impl="einsum", n_experts=E, topk=K,
                        capacity_factor=8.0)
    y2, lb2 = moe_apply(tp, "moe", p, x, impl="gather", n_experts=E, topk=K,
                        capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(lb1), np.asarray(lb2), rtol=1e-5)


def test_moe_lb_per_example_isolation():
    """Changing example j must not change example i's load-balance loss."""
    from repro.models.moe import moe_apply, moe_init
    D, F, E, K = 8, 12, 4, 2
    tree = moe_init(jax.random.PRNGKey(6), D, F, E)
    p = cm.split_tree(tree)[0]
    rng = np.random.RandomState(6)
    x = jnp.array(rng.randn(3, 5, D), jnp.float32)
    tp = Tapper()
    _, lb = moe_apply(tp, "m", p, x, impl="einsum", n_experts=E, topk=K)
    x2 = x.at[2].set(jnp.array(rng.randn(5, D), jnp.float32))
    _, lb2 = moe_apply(tp, "m", p, x2, impl="einsum", n_experts=E, topk=K)
    np.testing.assert_allclose(np.asarray(lb[:2]), np.asarray(lb2[:2]),
                               rtol=1e-5)
