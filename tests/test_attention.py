"""Attention correctness: decode==train incrementally, sliding window,
MLA absorbed decode, chunked long-context path, MoE dispatch impls."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.tapper import Tapper
from repro.models import attention as attn
from repro.models import common as cm


def _gqa_params(key, D, H, KV, hd, qk_norm=False):
    tree = attn.gqa_init(key, D, H, KV, hd, qk_norm=qk_norm)
    return cm.split_tree(tree)[0]


def test_decode_matches_full_forward():
    D, H, KV, hd, B, T = 16, 4, 2, 8, 2, 10
    p = _gqa_params(jax.random.PRNGKey(0), D, H, KV, hd)
    rng = np.random.RandomState(0)
    x = jnp.array(rng.randn(B, T, D), jnp.float32)
    tp = Tapper()
    full, _ = attn.gqa_apply(tp, "a", p, x, n_heads=H, n_kv=KV, head_dim=hd,
                             causal=True)
    cache = attn.gqa_cache(B, T, KV, hd)
    outs = []
    for t in range(T):
        o, cache = attn.gqa_apply(tp, "a", p, x[:, t:t + 1], n_heads=H,
                                  n_kv=KV, head_dim=hd, cache=cache)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


def test_prefill_then_decode_matches_full():
    D, H, KV, hd, B, T = 16, 4, 4, 8, 2, 8
    p = _gqa_params(jax.random.PRNGKey(1), D, H, KV, hd)
    rng = np.random.RandomState(1)
    x = jnp.array(rng.randn(B, T, D), jnp.float32)
    tp = Tapper()
    full, _ = attn.gqa_apply(tp, "a", p, x, n_heads=H, n_kv=KV, head_dim=hd,
                             causal=True)
    cache = attn.gqa_cache(B, T, KV, hd)
    pre, cache = attn.gqa_apply(tp, "a", p, x[:, :5], n_heads=H, n_kv=KV,
                                head_dim=hd, cache=cache)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :5]),
                               rtol=2e-4, atol=2e-5)
    o5, cache = attn.gqa_apply(tp, "a", p, x[:, 5:6], n_heads=H, n_kv=KV,
                               head_dim=hd, cache=cache)
    np.testing.assert_allclose(np.asarray(o5[:, 0]), np.asarray(full[:, 5]),
                               rtol=2e-4, atol=2e-5)


def test_sliding_window_ring_cache():
    """Ring-buffer decode == full attention restricted to the window."""
    D, H, KV, hd, B, T, W = 16, 2, 2, 8, 1, 12, 4
    p = _gqa_params(jax.random.PRNGKey(2), D, H, KV, hd)
    rng = np.random.RandomState(2)
    x = jnp.array(rng.randn(B, T, D), jnp.float32)
    tp = Tapper()
    full, _ = attn.gqa_apply(tp, "a", p, x, n_heads=H, n_kv=KV, head_dim=hd,
                             causal=True, window=W)
    cache = attn.gqa_cache(B, W, KV, hd)  # ring size == window
    outs = []
    for t in range(T):
        o, cache = attn.gqa_apply(tp, "a", p, x[:, t:t + 1], n_heads=H,
                                  n_kv=KV, head_dim=hd, cache=cache,
                                  window=W)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


def test_chunked_equals_full():
    D, H, KV, hd, B, T = 16, 2, 2, 8, 2, 64
    rng = np.random.RandomState(3)
    q = jnp.array(rng.randn(B, T, H, hd), jnp.float32)
    k = jnp.array(rng.randn(B, T, H, hd), jnp.float32)
    v = jnp.array(rng.randn(B, T, H, hd), jnp.float32)
    full = attn.attend(q, k, v, causal=True, impl="xla")
    chunked = attn.sdpa_chunked(q, k, v, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("absorbed", [False, True])
def test_mla_decode_matches_train(absorbed):
    D, H = 24, 2
    kw = dict(n_heads=H, q_lora_rank=8, kv_lora_rank=12, qk_nope_dim=6,
              qk_rope_dim=4, v_head_dim=6)
    tree = attn.mla_init(jax.random.PRNGKey(4), D, H, q_lora_rank=8,
                         kv_lora_rank=12, qk_nope_dim=6, qk_rope_dim=4,
                         v_head_dim=6)
    p = cm.split_tree(tree)[0]
    rng = np.random.RandomState(4)
    B, T = 2, 7
    x = jnp.array(rng.randn(B, T, D), jnp.float32)
    tp = Tapper()
    full, _ = attn.mla_apply(tp, "m", p, x, **kw)
    cache = attn.mla_cache(B, T, 12, 4)
    outs = []
    for t in range(T):
        o, cache = attn.mla_apply(tp, "m", p, x[:, t:t + 1], cache=cache,
                                  absorbed_decode=absorbed, **kw)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=3e-4, atol=3e-5)


def test_moe_einsum_vs_gather():
    """Both dispatch impls compute the same MoE layer output with ample
    capacity (routing identical; only the slot bookkeeping differs)."""
    from repro.models.moe import moe_apply, moe_init
    D, F, E, K = 16, 24, 4, 2
    tree = moe_init(jax.random.PRNGKey(5), D, F, E)
    p = cm.split_tree(tree)[0]
    rng = np.random.RandomState(5)
    x = jnp.array(rng.randn(2, 6, D), jnp.float32)
    tp = Tapper()
    y1, lb1 = moe_apply(tp, "moe", p, x, impl="einsum", n_experts=E, topk=K,
                        capacity_factor=8.0)
    y2, lb2 = moe_apply(tp, "moe", p, x, impl="gather", n_experts=E, topk=K,
                        capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(lb1), np.asarray(lb2), rtol=1e-5)


# ---------------------------------------------------------------------------
# Flash kernel differential suite: Pallas flash (interpret mode) vs the
# chunked-scan reference vs plain _sdpa, forward and backward.


def _qkv(seed, B, T, H, hd, S=None, Hkv=None, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    S = T if S is None else S
    Hkv = H if Hkv is None else Hkv
    q = jnp.array(rng.randn(B, T, H, hd), dtype)
    k = jnp.array(rng.randn(B, S, Hkv, hd), dtype)
    v = jnp.array(rng.randn(B, S, Hkv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("kv_heads", [4, 2, 1])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_sdpa_gqa(kv_heads, causal):
    """Flash kernel (GQA folded inside the kernel) == repeat_kv + _sdpa
    == sdpa_chunked, across grouped-query rep factors 1/2/4."""
    from repro.kernels import flash_attn as fa
    B, T, H, hd = 2, 64, 4, 8
    q, k, v = _qkv(10 + kv_heads, B, T, H, hd, Hkv=kv_heads)
    got = fa.flash_attention(q, k, v, causal=causal, bq=16, bk=16,
                             interpret=True)
    kr, vr = attn.repeat_kv(k, H // kv_heads), attn.repeat_kv(v, H // kv_heads)
    mask = (attn._causal_mask(T, T) if causal
            else jnp.ones((1, 1, T, T), bool))
    want = attn._sdpa(q, kr, vr, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    if causal:
        chunked = attn.sdpa_chunked(q, kr, vr, chunk=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(chunked),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(8, 32), (32, 8)])
def test_flash_rectangular_blocks(bq, bk):
    """bq != bk block shapes traverse the same masked tiles."""
    from repro.kernels import flash_attn as fa
    q, k, v = _qkv(20, 2, 64, 2, 8, Hkv=1)
    got = fa.flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                             interpret=True)
    kr, vr = attn.repeat_kv(k, 2), attn.repeat_kv(v, 2)
    want = attn._sdpa(q, kr, vr, attn._causal_mask(64, 64))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_backward_matches_reference(causal):
    """custom_vjp blockwise backward == autodiff through _sdpa, for q, k
    and v grads, including the GQA head-fold in dk/dv."""
    from repro.kernels import flash_attn as fa
    B, T, H, hd = 2, 32, 4, 8
    q, k, v = _qkv(30, B, T, H, hd, Hkv=2)
    rng = np.random.RandomState(31)
    w = jnp.array(rng.randn(B, T, H, hd), jnp.float32)

    def f_flash(q, k, v):
        return jnp.sum(w * fa.flash_attention(q, k, v, causal=causal,
                                              bq=8, bk=8, interpret=True))

    def f_ref(q, k, v):
        kr, vr = attn.repeat_kv(k, 2), attn.repeat_kv(v, 2)
        mask = (attn._causal_mask(T, T) if causal
                else jnp.ones((1, 1, T, T), bool))
        return jnp.sum(w * attn._sdpa(q, kr, vr, mask))

    got = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=3e-4, atol=3e-5)


def test_flash_query_pad_and_key_raise():
    """T % bq != 0 is zero-padded and sliced back; S % bk != 0 raises the
    named shape error (key padding would corrupt the normalizer)."""
    from repro.kernels import flash_attn as fa
    q, k, v = _qkv(40, 1, 40, 2, 8)
    got = fa.flash_attention(q, k, v, causal=True, bq=16, bk=8,
                             interpret=True)
    want = attn._sdpa(q, k, v, attn._causal_mask(40, 40))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    with pytest.raises(fa.FlashShapeError):
        fa.flash_attention(q, k, v, causal=True, bq=16, bk=16,
                           interpret=True)
    with pytest.raises(fa.FlashShapeError):
        fa.flash_attention(q, k[:, :, :0], v[:, :, :0], causal=True,
                           bq=16, bk=8, interpret=True)


# -- attend() dispatch regressions (fixed paths) ----------------------------


def test_attend_flash_reachable_and_differentiable():
    """impl='flash' actually dispatches to the kernel path (not a silent
    xla fallback) and matches it; grads flow."""
    q, k, v = _qkv(50, 2, 32, 2, 8)
    got = attn.attend(q, k, v, causal=True, impl="flash")
    want = attn.attend(q, k, v, causal=True, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    g = jax.grad(lambda q: jnp.sum(
        attn.attend(q, k, v, causal=True, impl="flash") ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.abs(g).max()) > 0


def test_attend_flash_unsupported_raises_named():
    """window / offset / valid_len under impl='flash' raise the named
    error (catchable as NotImplementedError), never silently mis-mask."""
    q, k, v = _qkv(51, 1, 16, 2, 8)
    for kw in ({"window": 4}, {"offset": 3}, {"valid_len": jnp.array(9)}):
        with pytest.raises(attn.FlashUnsupportedError):
            attn.attend(q, k, v, causal=True, impl="flash", **kw)
    assert issubclass(attn.FlashUnsupportedError, NotImplementedError)


def test_attend_chunked_threads_valid_len():
    """Regression: impl='chunked' honours valid_len (cache semantics) the
    same way the xla path does, with and without a window."""
    q, k, v = _qkv(52, 2, 16, 2, 8, S=24)
    vl = jnp.array(20)
    for kw in ({}, {"window": 6}):
        want = attn.attend(q, k, v, causal=True, impl="xla",
                           valid_len=vl, **kw)
        got = attn.attend(q, k, v, causal=True, impl="chunked",
                          valid_len=vl, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)


def test_attend_chunked_clamps_chunk_to_seq():
    """Regression: short sequences no longer crash the chunked path —
    attend() clamps the chunk to T before dispatching."""
    q, k, v = _qkv(53, 2, 8, 2, 8)
    got = attn.attend(q, k, v, causal=True, impl="chunked")
    want = attn.attend(q, k, v, causal=True, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_sdpa_chunked_indivisible_raises():
    q, k, v = _qkv(54, 1, 10, 2, 8)
    with pytest.raises(ValueError, match="not divisible"):
        attn.sdpa_chunked(q, k, v, chunk=4)


def test_moe_lb_per_example_isolation():
    """Changing example j must not change example i's load-balance loss."""
    from repro.models.moe import moe_apply, moe_init
    D, F, E, K = 8, 12, 4, 2
    tree = moe_init(jax.random.PRNGKey(6), D, F, E)
    p = cm.split_tree(tree)[0]
    rng = np.random.RandomState(6)
    x = jnp.array(rng.randn(3, 5, D), jnp.float32)
    tp = Tapper()
    _, lb = moe_apply(tp, "m", p, x, impl="einsum", n_experts=E, topk=K)
    x2 = x.at[2].set(jnp.array(rng.randn(5, D), jnp.float32))
    _, lb2 = moe_apply(tp, "m", p, x2, impl="einsum", n_experts=E, topk=K)
    np.testing.assert_allclose(np.asarray(lb[:2]), np.asarray(lb2[:2]),
                               rtol=1e-5)
