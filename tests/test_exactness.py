"""Differential-testing oracle suite.

Every norm / contrib realization in :mod:`repro.core.kinds` — dense
gram/stream/rank1, segmented dense (MoE slots), embed segsum/gram/pe,
conv ghost/materialize (incl. stride + dilation + groups, fgc and bgc
impls), scale — is checked against a naive autodiff oracle: the jacobian
of the per-example loss vector (vmap-of-vjp semantics, valid even for
segmented layers where examples do not own contiguous batch rows).  Runs
across float32 and bfloat16.

The deterministic geometry grid always runs; when ``hypothesis`` is
available (CI installs requirements-dev.txt) randomized property tests
widen the geometry coverage.  The sharded pipeline must pass the same
oracle — see the ``multidevice``-marked test at the bottom, which the
multi-device CI lane runs on a forced 8-device host.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import true_norms_sq
from repro.core import (ClipPolicy, clipped_grad_sum,
                        clipped_grad_sum_detailed, ghost_norms,
                        resolve_budgets)
from repro.core.strategies import clip_coefficients
from repro.core.tapper import Tapper

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    settings.register_profile("exactness", max_examples=15, deadline=None)
    settings.load_profile("exactness")
except ImportError:                       # container without dev extras:
    HAVE_HYPOTHESIS = False               # the deterministic grid still runs

DTYPES = (jnp.float32, jnp.bfloat16)


def _tol(dtype):
    """Comparison tolerance per capture dtype.  bf16 has ~8 mantissa bits:
    inputs/cotangents are quantized before the f32-accumulated reductions,
    so realizations legitimately differ at the ~1e-2 relative level."""
    return (dict(rtol=3e-4, atol=1e-6) if dtype == jnp.float32
            else dict(rtol=6e-2, atol=2e-3))


def oracle_pe_grads(apply_fn, params, batch):
    """Naive per-example gradients: rows of the Jacobian of the (B,)
    per-example loss vector — one VJP per example, no layer algebra."""
    return jax.jacrev(lambda p: apply_fn(p, batch, Tapper()))(params)


def _assert_norms_match(apply_fn, params, batch, dtype, **norm_kw):
    want = np.asarray(true_norms_sq(oracle_pe_grads(apply_fn, params, batch)))
    _, got, _ = ghost_norms(apply_fn, params, batch, **norm_kw)
    np.testing.assert_allclose(np.asarray(got), want, **_tol(dtype))


def _sum_tol(dtype, scale):
    """Clipped-sum tolerance: the norm error propagates into the clip
    coefficients, so sums are a notch looser than the norms themselves."""
    if dtype == jnp.float32:
        return dict(rtol=3e-3, atol=3e-4 * scale)
    return dict(rtol=1.2e-1, atol=2e-2 * scale)


def _oracle_clipped_sum(apply_fn, params, batch, C):
    pe = oracle_pe_grads(apply_fn, params, batch)
    coef = clip_coefficients(true_norms_sq(pe), C)
    return jax.tree.map(
        lambda g: jnp.einsum("b...,b->...", g.astype(jnp.float32), coef), pe)


def _assert_clipped_sum_matches(apply_fn, params, batch, dtype, C=0.1,
                                **kw):
    want = _oracle_clipped_sum(apply_fn, params, batch, C)
    _, got, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                                 check=True, **kw)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want)), 1.0)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            **_sum_tol(dtype, scale))


def _group_pe(pe_grads):
    """Split oracle per-example grads by top-level parameter group, in
    sorted-key order — the same deterministic group order the pipeline's
    budgets and per-layer norms use."""
    return [(k, pe_grads[k]) for k in sorted(pe_grads)]


def _oracle_per_layer_clipped_sum(apply_fn, params, batch, C,
                                  budgets=None):
    """Per-layer Jacobian-clip oracle: each parameter group clipped
    against its own budget and its own (naive-Jacobian) norm."""
    pe = oracle_pe_grads(apply_fn, params, batch)
    groups = _group_pe(pe)
    if budgets is None:
        budgets = np.full(len(groups), C / np.sqrt(len(groups)))
    out = {}
    for (key, sub), b in zip(groups, np.asarray(budgets)):
        coef = clip_coefficients(true_norms_sq(sub), b)
        out[key] = jax.tree.map(
            lambda g: jnp.einsum("b...,b->...", g.astype(jnp.float32), coef),
            sub)
    return out


def _assert_per_layer_matches(apply_fn, params, batch, dtype, C=0.1,
                              strategy="bk", **kw):
    want = _oracle_per_layer_clipped_sum(apply_fn, params, batch, C)
    _, got, _, detail = clipped_grad_sum_detailed(
        apply_fn, params, batch, l2_clip=C,
        strategy=strategy, clip_policy=ClipPolicy(mode="per_layer"),
        check=(strategy == "auto"), **kw)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want)), 1.0)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            **_sum_tol(dtype, scale))
    # The budgets the pipeline resolved must satisfy the sensitivity
    # invariant the oracle assumed.
    np.testing.assert_allclose(
        float(jnp.sum(jnp.square(detail["budgets"]))), C * C, rtol=1e-5)


# ---------------------------------------------------------------------------
# Single-kind model builders


def dense_seq_model(dtype, B=3, T=6, Di=5, Do=4, seed=0):
    rng = np.random.RandomState(seed)
    params = {"fc": {"w": jnp.asarray(rng.randn(Di, Do), dtype) * 0.5,
                     "b": jnp.asarray(rng.randn(Do), dtype) * 0.1}}

    def apply_fn(p, batch, tp):
        y = tp.dense("fc", batch["x"], p["fc"]["w"], p["fc"]["b"])
        return jnp.sum(jnp.tanh(y.astype(jnp.float32)) ** 2, axis=(1, 2))

    batch = {"x": jnp.asarray(rng.randn(B, T, Di), dtype)}
    return apply_fn, params, batch


def dense_novec_model(dtype, B=4, Di=6, Do=5, seed=1):
    rng = np.random.RandomState(seed)
    params = {"fc": {"w": jnp.asarray(rng.randn(Di, Do), dtype) * 0.5}}

    def apply_fn(p, batch, tp):
        y = tp.dense("fc", batch["x"], p["fc"]["w"])
        return jnp.sum(y.astype(jnp.float32) ** 2, axis=1)

    batch = {"x": jnp.asarray(rng.randn(B, Di), dtype)}
    return apply_fn, params, batch


def seg_dense_model(dtype, B=4, E=3, S=5, Di=4, Do=3, seed=2):
    """MoE-style dispatched slots: (E, S) slots with explicit example ids;
    an example's loss is the sum over its slots across all experts."""
    rng = np.random.RandomState(seed)
    params = {"ex": {"w": jnp.asarray(rng.randn(E, Di, Do), dtype) * 0.5}}
    seg = jnp.asarray(rng.randint(0, B, (E, S)))

    def apply_fn(p, batch, tp):
        y = tp.dense_segmented("ex", batch["x"], p["ex"]["w"], batch["seg"],
                               n_examples=B)
        v = jnp.sum(jnp.tanh(y.astype(jnp.float32)) ** 2, axis=-1)  # (E, S)
        return jnp.zeros((B,), jnp.float32).at[
            batch["seg"].reshape(-1)].add(v.reshape(-1))

    batch = {"x": jnp.asarray(rng.randn(E, S, Di), dtype), "seg": seg}
    return apply_fn, params, batch


def embed_model(dtype, B=3, T=7, V=13, D=4, seed=3):
    rng = np.random.RandomState(seed)
    params = {"emb": {"emb": jnp.asarray(rng.randn(V, D), dtype) * 0.5}}

    def apply_fn(p, batch, tp):
        e = tp.embed("emb", p["emb"]["emb"], batch["ids"])
        return jnp.sum(jnp.tanh(e.astype(jnp.float32)) ** 2, axis=(1, 2))

    # repeated ids per example exercise the same-token cross terms
    batch = {"ids": jnp.asarray(rng.randint(0, V, (B, T)))}
    return apply_fn, params, batch


CONV_GEOMS = [
    # (C, D, HW, K, stride, padding, dilation, groups)
    (3, 4, 8, 3, 1, 1, 1, 1),     # vanilla
    (4, 6, 9, 3, 2, 1, 1, 1),     # strided
    (4, 6, 9, 3, 1, 2, 2, 1),     # dilated
    (4, 8, 8, 3, 1, 1, 1, 4),     # grouped
    (6, 6, 9, 3, 2, 2, 2, 2),     # strided + dilated + grouped
]


def conv_model(dtype, geom, B=3, seed=4):
    C, D, HW, K, s, p_, dil, g = geom
    rng = np.random.RandomState(seed)
    params = {"c": {"w": jnp.asarray(rng.randn(D, C // g, K, K), dtype) * 0.3,
                    "b": jnp.asarray(rng.randn(D), dtype) * 0.1}}

    def apply_fn(p, batch, tp):
        y = tp.conv("c", batch["x"], p["c"]["w"], p["c"]["b"], stride=s,
                    padding=p_, dilation=dil, groups=g)
        return jnp.sum(jnp.tanh(y.astype(jnp.float32)) ** 2,
                       axis=tuple(range(1, y.ndim)))

    batch = {"x": jnp.asarray(rng.randn(B, C, HW, HW), dtype)}
    return apply_fn, params, batch


def scale_model(dtype, B=4, T=5, D=6, seed=5):
    rng = np.random.RandomState(seed)
    params = {"s": {"g": jnp.asarray(1 + 0.3 * rng.randn(D), dtype),
                    "b": jnp.asarray(rng.randn(D), dtype) * 0.1}}

    def apply_fn(p, batch, tp):
        y = tp.scale("s", batch["x"], p["s"]["g"], p["s"]["b"])
        return jnp.sum(jnp.tanh(y.astype(jnp.float32)) ** 2, axis=(1, 2))

    batch = {"x": jnp.asarray(rng.randn(B, T, D), dtype)}
    return apply_fn, params, batch


# ---------------------------------------------------------------------------
# Dense: gram / stream / rank1


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("gram", "stream", "auto"))
def test_dense_norms_match_oracle(method, dtype):
    apply_fn, params, batch = dense_seq_model(dtype)
    _assert_norms_match(apply_fn, params, batch, dtype, norm_method=method)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_dense_rank1_norms_match_oracle(dtype):
    apply_fn, params, batch = dense_novec_model(dtype)
    _assert_norms_match(apply_fn, params, batch, dtype, norm_method="rank1")


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("strategy", ("bk", "auto"))
def test_dense_clipped_sum_matches_oracle(strategy, dtype):
    apply_fn, params, batch = dense_seq_model(dtype)
    _assert_clipped_sum_matches(apply_fn, params, batch, dtype,
                                strategy=strategy)


# ---------------------------------------------------------------------------
# Segmented dense (MoE expert slots)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("gram", "stream"))
def test_seg_dense_norms_match_oracle(method, dtype):
    apply_fn, params, batch = seg_dense_model(dtype)
    _assert_norms_match(apply_fn, params, batch, dtype, norm_method=method)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_seg_dense_clipped_sum_matches_oracle(dtype):
    apply_fn, params, batch = seg_dense_model(dtype)
    _assert_clipped_sum_matches(apply_fn, params, batch, dtype,
                                strategy="bk")


# ---------------------------------------------------------------------------
# Embedding: segsum / gram / pe


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("segsum", "gram", "pe"))
def test_embed_norms_match_oracle(method, dtype):
    apply_fn, params, batch = embed_model(dtype)
    _assert_norms_match(apply_fn, params, batch, dtype, embed_method=method)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_embed_clipped_sum_matches_oracle(dtype):
    apply_fn, params, batch = embed_model(dtype)
    _assert_clipped_sum_matches(apply_fn, params, batch, dtype,
                                strategy="bk")


# ---------------------------------------------------------------------------
# Conv: ghost (im2col Gram) vs materialize, across geometry and impls


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("ghost", "pe"))
@pytest.mark.parametrize("geom", CONV_GEOMS,
                         ids=[f"C{c}D{d}s{s}d{dl}g{g}"
                              for c, d, _, _, s, _, dl, g in CONV_GEOMS])
def test_conv_norms_match_oracle(geom, method, dtype):
    apply_fn, params, batch = conv_model(dtype, geom)
    _assert_norms_match(apply_fn, params, batch, dtype, conv_norm=method)


@pytest.mark.parametrize("impl", ("fgc", "bgc"))
@pytest.mark.parametrize("geom", (CONV_GEOMS[1], CONV_GEOMS[4]),
                         ids=("strided", "mixed"))
def test_conv_pe_grad_impls_match_oracle(geom, impl):
    apply_fn, params, batch = conv_model(jnp.float32, geom)
    want = oracle_pe_grads(apply_fn, params, batch)
    from repro.core.strategies import crb_per_example_grads
    _, got = crb_per_example_grads(apply_fn, params, batch, conv_impl=impl)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_conv_clipped_sum_matches_oracle(dtype):
    apply_fn, params, batch = conv_model(dtype, CONV_GEOMS[4])
    _assert_clipped_sum_matches(apply_fn, params, batch, dtype,
                                strategy="auto")


# ---------------------------------------------------------------------------
# Scale (elementwise affine)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_scale_norms_match_oracle(dtype):
    apply_fn, params, batch = scale_model(dtype)
    _assert_norms_match(apply_fn, params, batch, dtype)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_scale_clipped_sum_matches_oracle(dtype):
    apply_fn, params, batch = scale_model(dtype)
    _assert_clipped_sum_matches(apply_fn, params, batch, dtype,
                                strategy="bk")


# ---------------------------------------------------------------------------
# Clipping modes: per-layer Jacobian-clip oracle and exactly-as-specified
# stale semantics, for every norm realization.  Per-layer clipping on a
# one-group model degenerates to flat (C_1 = C), so each kind-under-test
# is paired with a dense head — two parameter groups, two budgets.


def _head_loss(tp, p, feat):
    o = tp.dense("head", feat, p["head"]["w"])
    return jnp.sum(jnp.tanh(o.astype(jnp.float32)) ** 2, axis=1)


def _head_params(rng, Din, dtype, Do=3):
    return {"w": jnp.asarray(rng.randn(Din, Do), dtype) * 0.4}


def dense_plus_head_model(dtype, B=3, T=6, Di=5, Do=4, seed=10):
    rng = np.random.RandomState(seed)
    params = {"fc": {"w": jnp.asarray(rng.randn(Di, Do), dtype) * 0.5,
                     "b": jnp.asarray(rng.randn(Do), dtype) * 0.1},
              "head": _head_params(rng, Do, dtype)}

    def apply_fn(p, batch, tp):
        y = tp.dense("fc", batch["x"], p["fc"]["w"], p["fc"]["b"])
        return _head_loss(tp, p, jnp.tanh(y.astype(jnp.float32)).mean(1))

    return apply_fn, params, {"x": jnp.asarray(rng.randn(B, T, Di), dtype)}


def seg_dense_plus_head_model(dtype, B=4, E=3, S=5, Di=4, Do=3, seed=11):
    rng = np.random.RandomState(seed)
    params = {"ex": {"w": jnp.asarray(rng.randn(E, Di, Do), dtype) * 0.5},
              "head": _head_params(rng, Di, dtype)}
    seg = jnp.asarray(rng.randint(0, B, (E, S)))

    def apply_fn(p, batch, tp):
        y = tp.dense_segmented("ex", batch["x"], p["ex"]["w"], batch["seg"],
                               n_examples=B)
        v = jnp.sum(jnp.tanh(y.astype(jnp.float32)) ** 2, axis=-1)
        seg_loss = jnp.zeros((B,), jnp.float32).at[
            batch["seg"].reshape(-1)].add(v.reshape(-1))
        return seg_loss + _head_loss(tp, p, batch["h"])

    batch = {"x": jnp.asarray(rng.randn(E, S, Di), dtype), "seg": seg,
             "h": jnp.asarray(rng.randn(B, Di), dtype)}
    return apply_fn, params, batch


def embed_plus_head_model(dtype, B=3, T=7, V=13, D=4, seed=12):
    rng = np.random.RandomState(seed)
    params = {"emb": {"emb": jnp.asarray(rng.randn(V, D), dtype) * 0.5},
              "head": _head_params(rng, D, dtype)}

    def apply_fn(p, batch, tp):
        e = tp.embed("emb", p["emb"]["emb"], batch["ids"])
        return _head_loss(tp, p, jnp.tanh(e.astype(jnp.float32)).mean(1))

    return apply_fn, params, {"ids": jnp.asarray(rng.randint(0, V, (B, T)))}


def conv_plus_head_model(dtype, geom, B=3, seed=13):
    C, D, HW, K, s, p_, dil, g = geom
    rng = np.random.RandomState(seed)
    params = {"c": {"w": jnp.asarray(rng.randn(D, C // g, K, K), dtype) * 0.3,
                    "b": jnp.asarray(rng.randn(D), dtype) * 0.1},
              "head": _head_params(rng, D, dtype)}

    def apply_fn(p, batch, tp):
        y = tp.conv("c", batch["x"], p["c"]["w"], p["c"]["b"], stride=s,
                    padding=p_, dilation=dil, groups=g)
        return _head_loss(
            tp, p, jnp.tanh(y.astype(jnp.float32)).mean(axis=(2, 3)))

    return apply_fn, params, {"x": jnp.asarray(rng.randn(B, C, HW, HW),
                                               dtype)}


def scale_plus_head_model(dtype, B=4, T=5, D=6, seed=14):
    rng = np.random.RandomState(seed)
    params = {"s": {"g": jnp.asarray(1 + 0.3 * rng.randn(D), dtype),
                    "b": jnp.asarray(rng.randn(D), dtype) * 0.1},
              "head": _head_params(rng, D, dtype)}

    def apply_fn(p, batch, tp):
        y = tp.scale("s", batch["x"], p["s"]["g"], p["s"]["b"])
        return _head_loss(tp, p, jnp.tanh(y.astype(jnp.float32)).mean(1))

    return apply_fn, params, {"x": jnp.asarray(rng.randn(B, T, D), dtype)}


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("gram", "stream", "rank1"))
def test_per_layer_dense_matches_oracle(method, dtype):
    # rank1 needs no sequence axis: mean-pool the input first.
    T = 1 if method == "rank1" else 6
    apply_fn, params, batch = dense_plus_head_model(dtype, T=T)
    _assert_per_layer_matches(apply_fn, params, batch, dtype,
                              norm_method=method)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("gram", "stream"))
def test_per_layer_seg_dense_matches_oracle(method, dtype):
    apply_fn, params, batch = seg_dense_plus_head_model(dtype)
    _assert_per_layer_matches(apply_fn, params, batch, dtype,
                              norm_method=method)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("segsum", "gram", "pe"))
def test_per_layer_embed_matches_oracle(method, dtype):
    apply_fn, params, batch = embed_plus_head_model(dtype)
    _assert_per_layer_matches(apply_fn, params, batch, dtype,
                              embed_method=method)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("ghost", "pe"))
@pytest.mark.parametrize("geom", (CONV_GEOMS[0], CONV_GEOMS[4]),
                         ids=("vanilla", "mixed"))
def test_per_layer_conv_matches_oracle(geom, method, dtype):
    apply_fn, params, batch = conv_plus_head_model(dtype, geom)
    _assert_per_layer_matches(apply_fn, params, batch, dtype,
                              conv_norm=method)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_per_layer_scale_matches_oracle(dtype):
    apply_fn, params, batch = scale_plus_head_model(dtype)
    _assert_per_layer_matches(apply_fn, params, batch, dtype)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("build", (dense_plus_head_model,
                                   embed_plus_head_model,
                                   scale_plus_head_model),
                         ids=("dense", "embed", "scale"))
def test_per_layer_planned_matches_oracle(build, dtype):
    """The planned (auto) pipeline under per-layer clipping, with the
    planner choosing realizations."""
    apply_fn, params, batch = build(dtype)
    _assert_per_layer_matches(apply_fn, params, batch, dtype,
                              strategy="auto")


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_per_layer_weighted_budgets_match_oracle(dtype):
    """A non-uniform {glob: weight} split: the oracle clips with the same
    resolved budgets the pipeline uses."""
    apply_fn, params, batch = conv_plus_head_model(dtype, CONV_GEOMS[0])
    C = 0.1
    policy = ClipPolicy(mode="per_layer", budgets={"c": 3.0, "head": 1.0})
    budgets = resolve_budgets(policy, C, ("c", "head"))
    want = _oracle_per_layer_clipped_sum(apply_fn, params, batch, C,
                                         budgets=np.asarray(budgets))
    _, got, _, _ = clipped_grad_sum_detailed(
        apply_fn, params, batch, l2_clip=C, strategy="auto",
        clip_policy=policy, check=True)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want)), 1.0)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            **_sum_tol(dtype, scale))


STALE_BUILDERS = (
    ("dense", dense_plus_head_model),
    ("seg_dense", seg_dense_plus_head_model),
    ("embed", embed_plus_head_model),
    ("conv", lambda dtype: conv_plus_head_model(dtype, CONV_GEOMS[4])),
    ("scale", scale_plus_head_model),
)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("build", [b for _, b in STALE_BUILDERS],
                         ids=[n for n, _ in STALE_BUILDERS])
def test_stale_bitwise_reproduces_flat(build, dtype):
    """Exactly-as-specified-stale: fed the previous step's norms (here:
    the flat run's own norms on the same batch), a stale step with the
    fused realizations disabled is *bitwise* the flat step — same
    computation, lagged coefficients — and returns bitwise the same
    current norms for the next step."""
    apply_fn, params, batch = build(dtype)
    C = 0.1
    _, want, prev_ns, _ = clipped_grad_sum_detailed(
        apply_fn, params, batch, l2_clip=C, strategy="auto")
    _, got, cur_ns, _ = clipped_grad_sum_detailed(
        apply_fn, params, batch, l2_clip=C, strategy="auto",
        clip_policy=ClipPolicy(mode="stale", fused=False),
        prev_norms_sq=prev_ns)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert g.dtype == w.dtype and bool(jnp.all(g == w)), \
            "stale(fused=False) must be bitwise the flat result"
    assert bool(jnp.all(cur_ns == prev_ns))


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("build", [b for _, b in STALE_BUILDERS],
                         ids=[n for n, _ in STALE_BUILDERS])
def test_stale_fused_matches_oracle(build, dtype):
    """The fused single-pass realizations (gram_norm_fused where the plan
    marks them) reproduce the oracle's clipped sum when fed the oracle's
    norms — same tolerance bar as every other realization."""
    apply_fn, params, batch = build(dtype)
    C = 0.1
    pe = oracle_pe_grads(apply_fn, params, batch)
    prev_ns = true_norms_sq(pe)
    coef = clip_coefficients(prev_ns, C)
    want = jax.tree.map(
        lambda g: jnp.einsum("b...,b->...", g.astype(jnp.float32), coef), pe)
    _, got, cur_ns, _ = clipped_grad_sum_detailed(
        apply_fn, params, batch, l2_clip=C, strategy="auto",
        clip_policy=ClipPolicy(mode="stale", fused=True),
        prev_norms_sq=jnp.asarray(prev_ns))
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want)), 1.0)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            **_sum_tol(dtype, scale))
    # the pass's own norms (next step's coefficients) stay oracle-exact
    np.testing.assert_allclose(np.asarray(cur_ns), np.asarray(prev_ns),
                               **_tol(dtype))


# ---------------------------------------------------------------------------
# Hypothesis-driven geometry sweeps (CI installs requirements-dev.txt)


if HAVE_HYPOTHESIS:

    @given(st.integers(2, 12), st.integers(2, 8), st.integers(2, 8),
           st.integers(0, 99), st.sampled_from(["gram", "stream"]))
    def test_dense_norm_property(T, Di, Do, seed, method):
        apply_fn, params, batch = dense_seq_model(
            jnp.float32, B=3, T=T, Di=Di, Do=Do, seed=seed)
        _assert_norms_match(apply_fn, params, batch, jnp.float32,
                            norm_method=method)

    @given(st.integers(1, 2), st.integers(1, 2), st.integers(0, 2),
           st.sampled_from([1, 2]), st.integers(0, 99))
    def test_conv_ghost_norm_property(stride, dilation, padding, groups,
                                      seed):
        C = 4 * groups
        D = 2 * groups
        geom = (C, D, 8, 3, stride, padding, dilation, groups)
        apply_fn, params, batch = conv_model(jnp.float32, geom, seed=seed)
        _assert_norms_match(apply_fn, params, batch, jnp.float32,
                            conv_norm="ghost")

    @given(st.integers(2, 10), st.integers(2, 6), st.integers(5, 16),
           st.integers(0, 99), st.sampled_from(["segsum", "gram", "pe"]))
    def test_embed_norm_property(T, D, V, seed, method):
        apply_fn, params, batch = embed_model(jnp.float32, B=3, T=T, V=V,
                                              D=D, seed=seed)
        _assert_norms_match(apply_fn, params, batch, jnp.float32,
                            embed_method=method)

    @given(st.integers(2, 10), st.integers(2, 8), st.integers(2, 8),
           st.integers(0, 99), st.sampled_from(["gram", "stream"]))
    def test_per_layer_dense_property(T, Di, Do, seed, method):
        apply_fn, params, batch = dense_plus_head_model(
            jnp.float32, B=3, T=T, Di=Di, Do=Do, seed=seed)
        _assert_per_layer_matches(apply_fn, params, batch, jnp.float32,
                                  norm_method=method)

    @given(st.integers(1, 2), st.integers(1, 2), st.sampled_from([1, 2]),
           st.integers(0, 99))
    def test_stale_fused_conv_property(stride, dilation, groups, seed):
        C_in = 4 * groups
        D = 2 * groups
        geom = (C_in, D, 8, 3, stride, 1, dilation, groups)
        apply_fn, params, batch = conv_plus_head_model(jnp.float32, geom,
                                                       seed=seed)
        _, want, prev_ns, _ = clipped_grad_sum_detailed(
            apply_fn, params, batch, l2_clip=0.1, strategy="auto")
        _, got, _, _ = clipped_grad_sum_detailed(
            apply_fn, params, batch, l2_clip=0.1, strategy="auto",
            clip_policy=ClipPolicy(mode="stale", fused=True),
            prev_norms_sq=prev_ns)
        scale = max(max(float(jnp.abs(w).max())
                        for w in jax.tree.leaves(want)), 1.0)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(
                np.asarray(g, np.float32), np.asarray(w, np.float32),
                **_sum_tol(jnp.float32, scale))


# ---------------------------------------------------------------------------
# The sharded pipeline passes the same oracle (8-device CI lane)


def _grad_extracting_optimizer(grads, state, params, *, lr, weight_decay):
    """Identity 'optimizer' that surfaces the pipeline's gradient as the
    new params, so the sharded jitted step's output IS the gradient."""
    return grads, state


@pytest.mark.multidevice
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_sharded_engine_passes_oracle(dtype):
    """The mesh-planned, explicitly sharded private step must reproduce
    the naive oracle's clipped mean gradient — same exactness bar as the
    single-device realizations above."""
    from repro.core import DPConfig, PrivacyEngine

    apply_fn, params, batch = conv_model(dtype, CONV_GEOMS[1], B=8, seed=7)
    mesh = jax.make_mesh((8,), ("data",))
    C = 0.1
    engine = PrivacyEngine(apply_fn, params, batch, dp=DPConfig(l2_clip=C),
                           optimizer=_grad_extracting_optimizer, mesh=mesh)
    got_grad, _, _, _ = engine.private_step(params, {"step": jnp.zeros(())},
                                            batch)
    B = batch["x"].shape[0]
    want = _oracle_clipped_sum(apply_fn, params, batch, C)
    want_grad = jax.tree.map(lambda g: g / B, want)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want_grad)), 1e-3)
    for g, w in zip(jax.tree.leaves(got_grad), jax.tree.leaves(want_grad)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   **_sum_tol(dtype, scale))


@pytest.mark.multidevice
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_sharded_per_layer_passes_oracle(dtype):
    """Per-layer clipping under the explicitly sharded step: per-layer
    per-example norms reduce over the data axes under SPMD (each group's
    coefficients see the psum'd group norm) and the result matches the
    per-layer Jacobian-clip oracle."""
    from repro.core import ClipPolicy, DPConfig, PrivacyEngine

    apply_fn, params, batch = conv_plus_head_model(dtype, CONV_GEOMS[1],
                                                   B=8, seed=7)
    mesh = jax.make_mesh((8,), ("data",))
    C = 0.1
    engine = PrivacyEngine(
        apply_fn, params, batch,
        dp=DPConfig(l2_clip=C, clipping=ClipPolicy(mode="per_layer")),
        optimizer=_grad_extracting_optimizer, mesh=mesh)
    got_grad, _, _, aux = engine.private_step(
        params, {"step": jnp.zeros(())}, batch)
    B = batch["x"].shape[0]
    want = _oracle_per_layer_clipped_sum(apply_fn, params, batch, C)
    want_grad = jax.tree.map(lambda g: g / B, want)
    assert aux["per_layer_clip_fraction"].shape == (2,)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want_grad)), 1e-3)
    for g, w in zip(jax.tree.leaves(got_grad), jax.tree.leaves(want_grad)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   **_sum_tol(dtype, scale))


@pytest.mark.multidevice
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_sharded_stale_passes_oracle(dtype):
    """Stale clipping under the sharded step: the bootstrap step clips
    exactly (flat oracle), and the steady step — fed the bootstrap's
    norms on the same batch — reproduces the flat oracle too (the lagged
    norms coincide with the current ones)."""
    from repro.core import ClipPolicy, DPConfig, PrivacyEngine

    apply_fn, params, batch = conv_plus_head_model(dtype, CONV_GEOMS[1],
                                                   B=8, seed=7)
    mesh = jax.make_mesh((8,), ("data",))
    C = 0.1
    engine = PrivacyEngine(
        apply_fn, params, batch,
        dp=DPConfig(l2_clip=C, clipping=ClipPolicy(mode="stale")),
        optimizer=_grad_extracting_optimizer, mesh=mesh)
    opt0 = {"step": jnp.zeros(())}
    B = batch["x"].shape[0]
    want = _oracle_clipped_sum(apply_fn, params, batch, C)
    want_grad = jax.tree.map(lambda g: g / B, want)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want_grad)), 1e-3)
    boot_grad, _, _, aux = engine.private_step(params, opt0, batch)
    steady_grad, _, _, aux2 = engine.private_step(params, opt0, batch)
    assert "clip_fraction_lagged" in aux and "clip_fraction_lagged" in aux2
    for got in (boot_grad, steady_grad):
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want_grad)):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32),
                                       **_sum_tol(dtype, scale))


# ---------------------------------------------------------------------------
# 2D (data x model) meshes: tensor-sharded layers pass the same oracle

_CONV_2D_AXES = {"c": {"w": ("mlp", None, None, "conv_k"), "b": ("mlp",)}}
_CONV_HEAD_2D_AXES = {"c": {"w": ("mlp", None, None, "conv_k"),
                            "b": ("mlp",)},
                      "head": {"w": ("embed", "mlp")}}


@pytest.mark.multidevice
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_sharded_2d_engine_passes_oracle(dtype):
    """data:4,model:2 — conv params partitioned over the model axis
    (out-channels), batch over data.  GSPMD psums the partial-Gram norm
    contributions over ``model`` and the (B,) norms over ``data``; the
    tensor-sharded step's clipped mean gradient must still match the
    naive Jacobian oracle exactly."""
    from repro.core import DPConfig, PrivacyEngine

    apply_fn, params, batch = conv_model(dtype, CONV_GEOMS[1], B=8, seed=7)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    C = 0.1
    engine = PrivacyEngine(apply_fn, params, batch, dp=DPConfig(l2_clip=C),
                           optimizer=_grad_extracting_optimizer, mesh=mesh,
                           param_axes=_CONV_2D_AXES, calibration="analytic")
    got_grad, _, _, _ = engine.private_step(params, {"step": jnp.zeros(())},
                                            batch)
    # the step really is tensor-sharded: conv weight partitioned on its
    # out-channel dim, not replicated
    w_spec = got_grad["c"]["w"].sharding.spec
    assert tuple(w_spec)[:1] == ("model",), w_spec
    B = batch["x"].shape[0]
    want = _oracle_clipped_sum(apply_fn, params, batch, C)
    want_grad = jax.tree.map(lambda g: g / B, want)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want_grad)), 1e-3)
    for g, w in zip(jax.tree.leaves(got_grad), jax.tree.leaves(want_grad)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   **_sum_tol(dtype, scale))


@pytest.mark.multidevice
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_sharded_2d_per_layer_passes_oracle(dtype):
    """Per-layer clipping on the 2D mesh: each group's per-example norm
    is psum'd over both axes exactly once (model partials + data
    examples) before the coefficients, matching the per-layer oracle.
    The 3-wide head does not divide the model axis and stays replicated
    — the mixed sharded/replicated layout is the production case."""
    from repro.core import ClipPolicy, DPConfig, PrivacyEngine

    apply_fn, params, batch = conv_plus_head_model(dtype, CONV_GEOMS[1],
                                                   B=8, seed=7)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    C = 0.1
    engine = PrivacyEngine(
        apply_fn, params, batch,
        dp=DPConfig(l2_clip=C, clipping=ClipPolicy(mode="per_layer")),
        optimizer=_grad_extracting_optimizer, mesh=mesh,
        param_axes=_CONV_HEAD_2D_AXES, calibration="analytic")
    got_grad, _, _, aux = engine.private_step(
        params, {"step": jnp.zeros(())}, batch)
    assert tuple(got_grad["c"]["w"].sharding.spec)[:1] == ("model",)
    assert got_grad["head"]["w"].sharding.is_fully_replicated
    B = batch["x"].shape[0]
    want = _oracle_per_layer_clipped_sum(apply_fn, params, batch, C)
    want_grad = jax.tree.map(lambda g: g / B, want)
    assert aux["per_layer_clip_fraction"].shape == (2,)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want_grad)), 1e-3)
    for g, w in zip(jax.tree.leaves(got_grad), jax.tree.leaves(want_grad)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   **_sum_tol(dtype, scale))


@pytest.mark.multidevice
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_sharded_2d_stale_passes_oracle(dtype):
    """Stale clipping on the 2D mesh: bootstrap and steady step (lagged
    norms == current norms on a repeated batch) both match the flat
    oracle with tensor-sharded params."""
    from repro.core import ClipPolicy, DPConfig, PrivacyEngine

    apply_fn, params, batch = conv_plus_head_model(dtype, CONV_GEOMS[1],
                                                   B=8, seed=7)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    C = 0.1
    engine = PrivacyEngine(
        apply_fn, params, batch,
        dp=DPConfig(l2_clip=C, clipping=ClipPolicy(mode="stale")),
        optimizer=_grad_extracting_optimizer, mesh=mesh,
        param_axes=_CONV_HEAD_2D_AXES, calibration="analytic")
    opt0 = {"step": jnp.zeros(())}
    B = batch["x"].shape[0]
    want = _oracle_clipped_sum(apply_fn, params, batch, C)
    want_grad = jax.tree.map(lambda g: g / B, want)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want_grad)), 1e-3)
    boot_grad, _, _, _ = engine.private_step(params, opt0, batch)
    steady_grad, _, _, _ = engine.private_step(params, opt0, batch)
    for got in (boot_grad, steady_grad):
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want_grad)):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32),
                                       **_sum_tol(dtype, scale))


# ---------------------------------------------------------------------------
# Block-level attention realization ("attn" kind): the whole GQA/MLA
# block tapped as one unit, per-example norms from a layer-local
# recompute (ghost) or materialized per-example grads (pe), vs the
# naive Jacobian oracle.


def gqa_attn_plus_head_model(dtype, B=4, T=8, D=16, H=4, KV=2, hd=4,
                             seed=15, qk_norm=False):
    from repro.models import attention as attn_mod
    from repro.models import common as cm
    tree = attn_mod.gqa_init(jax.random.PRNGKey(seed), D, H, KV, hd,
                             qk_norm=qk_norm, dtype=dtype)
    rng = np.random.RandomState(seed)
    params = {"attn": cm.split_tree(tree)[0],
              "head": _head_params(rng, D, dtype)}

    def apply_fn(p, batch, tp):
        y, _ = attn_mod.gqa_apply(tp, "attn", p["attn"], batch["x"],
                                  n_heads=H, n_kv=KV, head_dim=hd,
                                  qk_norm=qk_norm, dp_attn=True)
        return _head_loss(tp, p, jnp.tanh(y.astype(jnp.float32)).mean(1))

    return apply_fn, params, {"x": jnp.asarray(rng.randn(B, T, D) * 0.5,
                                               dtype)}


_MLA_KW = dict(q_lora_rank=8, kv_lora_rank=8, qk_nope_dim=4,
               qk_rope_dim=4, v_head_dim=4)


def mla_attn_plus_head_model(dtype, B=4, T=6, D=16, H=2, seed=16):
    from repro.models import attention as attn_mod
    from repro.models import common as cm
    tree = attn_mod.mla_init(jax.random.PRNGKey(seed), D, H, dtype=dtype,
                             **_MLA_KW)
    rng = np.random.RandomState(seed)
    params = {"attn": cm.split_tree(tree)[0],
              "head": _head_params(rng, D, dtype)}

    def apply_fn(p, batch, tp):
        y, _ = attn_mod.mla_apply(tp, "attn", p["attn"], batch["x"],
                                  n_heads=H, dp_attn=True, **_MLA_KW)
        return _head_loss(tp, p, jnp.tanh(y.astype(jnp.float32)).mean(1))

    return apply_fn, params, {"x": jnp.asarray(rng.randn(B, T, D) * 0.5,
                                               dtype)}


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("ghost", "pe"))
@pytest.mark.parametrize("qk_norm", (False, True), ids=("plain", "qknorm"))
def test_attn_gqa_norms_match_oracle(qk_norm, method, dtype):
    apply_fn, params, batch = gqa_attn_plus_head_model(dtype,
                                                       qk_norm=qk_norm)
    _assert_norms_match(apply_fn, params, batch, dtype, attn_norm=method)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("method", ("ghost", "pe"))
def test_attn_mla_norms_match_oracle(method, dtype):
    apply_fn, params, batch = mla_attn_plus_head_model(dtype)
    _assert_norms_match(apply_fn, params, batch, dtype, attn_norm=method)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("strategy", ("ghost", "auto"))
def test_attn_clipped_sum_matches_oracle(strategy, dtype):
    apply_fn, params, batch = gqa_attn_plus_head_model(dtype)
    _assert_clipped_sum_matches(apply_fn, params, batch, dtype,
                                strategy=strategy)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_attn_mla_clipped_sum_matches_oracle(dtype):
    apply_fn, params, batch = mla_attn_plus_head_model(dtype)
    _assert_clipped_sum_matches(apply_fn, params, batch, dtype,
                                strategy="auto")


def test_attn_planner_selects_realization():
    """Acceptance: the planner prices the block tap as its own "attn"
    kind and picks a non-materializing norm realization for it."""
    from repro.core import costmodel
    apply_fn, params, batch = gqa_attn_plus_head_model(jnp.float32)
    costmodel.clear_plan_cache()
    plan = costmodel.get_plan(apply_fn, params, batch)
    lp = plan.layers["attn"]
    assert lp.kind == "attn"
    assert lp.norm_method == "ghost"
    assert "attn" in plan.explain()


@pytest.mark.multidevice
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_sharded_attn_engine_passes_oracle(dtype):
    """The planned, explicitly sharded private step over the attn
    realization matches the naive oracle's clipped mean gradient on an
    8-device data mesh — same bar as the dense/conv lanes above."""
    from repro.core import DPConfig, PrivacyEngine, costmodel

    apply_fn, params, batch = gqa_attn_plus_head_model(dtype, B=8)
    mesh = jax.make_mesh((8,), ("data",))
    C = 0.1
    costmodel.clear_plan_cache()
    engine = PrivacyEngine(apply_fn, params, batch, dp=DPConfig(l2_clip=C),
                           optimizer=_grad_extracting_optimizer, mesh=mesh)
    got_grad, _, _, _ = engine.private_step(params, {"step": jnp.zeros(())},
                                            batch)
    B = batch["x"].shape[0]
    want = _oracle_clipped_sum(apply_fn, params, batch, C)
    want_grad = jax.tree.map(lambda g: g / B, want)
    scale = max(max(float(jnp.abs(w).max())
                    for w in jax.tree.leaves(want_grad)), 1e-3)
    for g, w in zip(jax.tree.leaves(got_grad), jax.tree.leaves(want_grad)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   **_sum_tol(dtype, scale))
