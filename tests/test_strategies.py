"""All five per-example-gradient strategies agree (the paper's Table-1
semantics: naive == multi == crb; ghost/bk are our extensions)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tree_maxdiff, true_norms_sq
from repro.core import (check_coverage, clipped_grad_sum, ghost_norms,
                        per_example_grads)

TOL = 2e-5


@pytest.fixture(scope="module")
def oracle(toy_model):
    apply_fn, params, batch = toy_model
    losses, pe = per_example_grads(apply_fn, params, batch, "naive")
    return losses, pe


def test_multi_equals_naive(toy_model, oracle):
    apply_fn, params, batch = toy_model
    losses_n, pe_n = oracle
    losses, pe = per_example_grads(apply_fn, params, batch, "multi")
    assert np.allclose(losses, losses_n, atol=TOL)
    assert tree_maxdiff(pe, pe_n) < TOL


def test_crb_equals_naive(toy_model, oracle):
    apply_fn, params, batch = toy_model
    losses_n, pe_n = oracle
    losses, pe = per_example_grads(apply_fn, params, batch, "crb")
    assert np.allclose(losses, losses_n, atol=TOL)
    assert tree_maxdiff(pe, pe_n) < TOL


def test_crb_bgc_variant(toy_model, oracle):
    apply_fn, params, batch = toy_model
    _, pe_n = oracle
    _, pe = per_example_grads(apply_fn, params, batch, "crb",
                              conv_impl="bgc")
    assert tree_maxdiff(pe, pe_n) < TOL


def test_crb_coverage_complete(toy_model):
    apply_fn, params, batch = toy_model
    _, pe = per_example_grads(apply_fn, params, batch, "crb")
    assert check_coverage(params, pe) == []


def test_ghost_norms_match(toy_model, oracle):
    apply_fn, params, batch = toy_model
    _, pe_n = oracle
    want = true_norms_sq(pe_n)
    _, got, _ = ghost_norms(apply_fn, params, batch)
    np.testing.assert_allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("method", ["gram", "stream"])
def test_ghost_norm_methods(toy_model, oracle, method):
    apply_fn, params, batch = toy_model
    _, pe_n = oracle
    want = true_norms_sq(pe_n)
    _, got, _ = ghost_norms(apply_fn, params, batch, norm_method=method)
    np.testing.assert_allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("strategy", ["multi", "crb", "ghost", "bk"])
def test_clipped_sums_agree(toy_model, strategy):
    apply_fn, params, batch = toy_model
    C = 0.05
    _, ref, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                                 strategy="naive")
    _, got, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                                 strategy=strategy)
    assert tree_maxdiff(got, ref) < TOL


def test_clip_bound_holds(toy_model):
    """Each clipped contribution has norm <= C -> the sum over B has norm
    <= B*C (the DP sensitivity bound)."""
    apply_fn, params, batch = toy_model
    C = 0.01
    B = batch["label"].shape[0]
    _, gsum, norms_sq = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                                         strategy="ghost")
    total = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                               for g in jax.tree.leaves(gsum))))
    assert total <= B * C * (1 + 1e-4)


def test_ghost_norm_pallas_method(toy_model, oracle):
    """norm_method='pallas' routes dense norms through the VMEM-tiled
    kernel (interpret mode on CPU) and stays exact."""
    apply_fn, params, batch = toy_model
    _, pe_n = oracle
    want = true_norms_sq(pe_n)
    _, got, _ = ghost_norms(apply_fn, params, batch, norm_method="pallas")
    np.testing.assert_allclose(got, want, rtol=1e-4)
