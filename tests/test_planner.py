"""The per-layer execution planner: cost-model crossovers, plan caching,
the one-forward/one-backward steady state, and auto == naive exactness on
a CNN config and a tied-embedding LM config."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tree_maxdiff, true_norms_sq
from repro.configs import get_config
from repro.core import clipped_grad_sum, costmodel, ghost_norms, kinds, \
    per_example_grads
from repro.core.tapper import STATS, LayerMeta
from repro.kernels import ops as kops
from repro.models.convops import conv_output_spatial
from repro.models.registry import build_model

TOL = 1e-4


# ---------------------------------------------------------------------------
# Cost-model crossovers (pinned: these are the paper's empirical regimes)


def test_dense_gram_stream_crossover():
    # Long sequence, modest width: streaming the per-example grads wins.
    assert costmodel.dense_norm_method(4096, 256, 256, 8) == "stream"
    # Short sequence, wide layer: the T² Gram trick wins.
    assert costmodel.dense_norm_method(64, 1024, 1024, 8) == "gram"
    # No sequence axis: exact rank-1 factorization.
    assert costmodel.dense_norm_method(1, 4096, 4096, 8) == "rank1"
    # Streaming is vetoed when the (B, Din, Dout) scratch blows the budget.
    assert costmodel.dense_norm_method(4096, 256, 256, 8,
                                       mem_budget=1 << 20) == "gram"


def test_conv_ghost_pe_crossover():
    # Early conv layer: large spatial output, few channels -> materialize
    # (the paper's Algorithm 2 regime).
    assert costmodel.conv_norm_method(T=64 * 64, C=3, D=64, K=121, B=8) == "pe"
    # Late conv layer: tiny spatial output, wide channels -> im2col ghost
    # norm (the mixed-clipping regime of Bu et al.).
    assert costmodel.conv_norm_method(T=4 * 4, C=512, D=512, K=9, B=8) \
        == "ghost"
    # Memory veto: pe scratch over budget falls back to the chunked ghost.
    assert costmodel.conv_norm_method(T=64 * 64, C=256, D=512, K=9, B=64,
                                      mem_budget=1 << 20) == "ghost"


def test_plan_is_mixed_on_toy_model(toy_model):
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch)
    methods = {n: lp.norm_method for n, lp in plan.layers.items()}
    # conv1 is an early layer (large T, 3 channels): materialized.
    assert methods["conv1"] == "pe"
    # the T=1 head is the exact rank-1 factorization.
    assert methods["head"] == "rank1"
    # at least two distinct norm realizations -> genuinely mixed.
    assert len(set(methods.values())) >= 2
    assert not plan.needs_backward


def test_plan_cache_roundtrip(toy_model):
    apply_fn, params, batch = toy_model
    costmodel.clear_plan_cache()
    p1 = costmodel.get_plan(apply_fn, params, batch)
    p2 = costmodel.get_plan(apply_fn, params, batch)
    assert p1 is p2
    assert costmodel.plan_cache_info()["size"] == 1
    # A different batch shape is a different plan.
    smaller = jax.tree.map(lambda a: a[:2], batch)
    p3 = costmodel.get_plan(apply_fn, params, smaller)
    assert p3 is not p1
    assert costmodel.plan_cache_info()["size"] == 2


# ---------------------------------------------------------------------------
# Steady-state execution counts: auto is 1 forward + 1 backward; ghost 2+2


def test_auto_single_forward_backward(toy_model):
    apply_fn, params, batch = toy_model
    costmodel.clear_plan_cache()
    STATS.reset()
    clipped_grad_sum(apply_fn, params, batch, l2_clip=0.1, strategy="auto")
    assert STATS.snapshot() == {"forwards": 1, "backwards": 1, "probes": 1}
    STATS.reset()
    clipped_grad_sum(apply_fn, params, batch, l2_clip=0.1, strategy="auto")
    # warm: the cached plan removes the probe; exactly one fwd + one bwd.
    assert STATS.snapshot() == {"forwards": 1, "backwards": 1, "probes": 0}
    STATS.reset()
    clipped_grad_sum(apply_fn, params, batch, l2_clip=0.1, strategy="ghost")
    assert STATS.forwards == 2 and STATS.backwards == 2


# ---------------------------------------------------------------------------
# auto == naive oracle


def test_auto_matches_naive_toy(toy_model):
    apply_fn, params, batch = toy_model
    C = 0.05
    _, ref, nref = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                                    strategy="naive")
    _, got, ngot = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                                    strategy="auto", check=True)
    assert tree_maxdiff(got, ref) < TOL
    np.testing.assert_allclose(np.asarray(ngot), np.asarray(nref), rtol=1e-4)


def test_auto_matches_naive_cnn():
    cfg = get_config("alexnet").replace(img_size=64, n_classes=10)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = {"img": jnp.array(rng.randn(2, 3, 64, 64), jnp.float32),
             "label": jnp.array(rng.randint(0, 10, (2,)))}
    _, ref, _ = clipped_grad_sum(model.apply, params, batch, l2_clip=1.0,
                                 strategy="naive")
    _, got, _ = clipped_grad_sum(model.apply, params, batch, l2_clip=1.0,
                                 strategy="auto", check=True)
    scale = max(float(jnp.abs(x).max()) for x in jax.tree.leaves(ref))
    assert tree_maxdiff(got, ref) < TOL * max(scale, 1.0)
    # AlexNet spans both conv regimes: the plan must actually mix.
    plan = costmodel.get_plan(model.apply, params, batch)
    conv_methods = {lp.norm_method for lp in plan.layers.values()
                    if lp.kind == "conv"}
    assert conv_methods == {"pe", "ghost"}


def test_auto_matches_naive_lm_tied():
    cfg = get_config("llama3.2-1b").reduced()
    assert cfg.tie_embeddings
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    batch = {"tokens": jnp.array(rng.randint(0, cfg.vocab, (3, 8))),
             "labels": jnp.array(rng.randint(0, cfg.vocab, (3, 8)))}
    _, pe = per_example_grads(model.apply, params, batch, "naive")
    want = true_norms_sq(pe)
    _, ref, _ = clipped_grad_sum(model.apply, params, batch, l2_clip=1.0,
                                 strategy="naive")
    _, got, ngot = clipped_grad_sum(model.apply, params, batch, l2_clip=1.0,
                                    strategy="auto", check=True)
    scale = max(float(jnp.abs(x).max()) for x in jax.tree.leaves(ref))
    assert tree_maxdiff(got, ref) < TOL * max(scale, 1.0)
    np.testing.assert_allclose(np.asarray(ngot), np.asarray(want), rtol=3e-4)


def test_auto_under_jit_and_microbatches(toy_model):
    from repro.core import DPConfig
    from repro.core.clipping import dp_gradient
    apply_fn, params, batch = toy_model
    ref = dp_gradient(apply_fn, params, batch,
                      cfg=DPConfig(l2_clip=0.1, strategy="bk"))
    dpc = DPConfig(l2_clip=0.1, strategy="auto", microbatches=2)
    loss, grad, aux = jax.jit(
        lambda p, b: dp_gradient(apply_fn, p, b, cfg=dpc))(params, batch)
    assert np.isfinite(float(loss))
    assert tree_maxdiff(grad, ref[1]) < TOL


# ---------------------------------------------------------------------------
# Conv ghost norm (im2col Gram) against the materializing oracle


@pytest.mark.parametrize("C,D,HW,K,s,p,dil,g", [
    (6, 8, 10, 3, 2, 1, 1, 1),    # strided + padded
    (8, 12, 9, 3, 1, 2, 2, 1),    # dilated
    (8, 12, 8, 3, 1, 1, 1, 4),    # grouped
])
def test_conv_ghost_norm_exact(C, D, HW, K, s, p, dil, g):
    rng = np.random.RandomState(2)
    B = 3
    x = jnp.array(rng.randn(B, C, HW, HW), jnp.float32)
    out_sp = conv_output_spatial((HW, HW), (K, K), s, dil, p)
    dy = jnp.array(rng.randn(B, D, *out_sp), jnp.float32)
    meta = LayerMeta("conv", ("c",), bias_key="b",
                     static={"stride": s, "dilation": dil, "padding": p,
                             "groups": g, "kernel_shape": (D, C // g, K, K)})
    n_pe = kinds.conv_norm_sq(meta, {"x": x}, dy, method="pe")
    n_gh = kinds.conv_norm_sq(meta, {"x": x}, dy, method="ghost")
    np.testing.assert_allclose(np.asarray(n_gh), np.asarray(n_pe), rtol=1e-4)


def test_ghost_norms_conv_ghost_mode(toy_model):
    apply_fn, params, batch = toy_model
    _, pe = per_example_grads(apply_fn, params, batch, "naive")
    want = true_norms_sq(pe)
    _, got, _ = ghost_norms(apply_fn, params, batch, conv_norm="ghost")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


# ---------------------------------------------------------------------------
# Fused Pallas kernel: norm + weighted contribution in one pass


def test_gram_norm_fused_kernel():
    rng = np.random.RandomState(3)
    B, T, Di, Do = 3, 20, 7, 9
    x = jnp.array(rng.randn(B, T, Di), jnp.float32)
    dy = jnp.array(rng.randn(B, T, Do), jnp.float32)
    w = jnp.array(rng.rand(B), jnp.float32)
    meta = LayerMeta("dense", ("p",), bias_key="b")
    n_ref = kinds.dense_norm_sq(meta, {"x": x}, dy, method="gram")
    c_ref = kinds.dense_contrib(meta, {"x": x}, dy, w)
    n, cw, cb = kops.gram_norm_fused(x, dy, w, has_bias=True, bt=8)
    np.testing.assert_allclose(np.asarray(n), np.asarray(n_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(cw), np.asarray(c_ref["w"]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(cb), np.asarray(c_ref["b"]),
                               atol=1e-4)


def test_dense_norm_and_contrib_methods():
    rng = np.random.RandomState(4)
    B, T, Di, Do = 2, 12, 5, 6
    x = jnp.array(rng.randn(B, T, Di), jnp.float32)
    dy = jnp.array(rng.randn(B, T, Do), jnp.float32)
    w = jnp.array(rng.rand(B), jnp.float32)
    meta = LayerMeta("dense", ("p",))
    c_ref = kinds.dense_contrib(meta, {"x": x}, dy, w)
    for method in ("pallas", "stream"):
        n, c = kinds.dense_norm_and_contrib(meta, {"x": x}, dy, w,
                                            method=method)
        np.testing.assert_allclose(np.asarray(c["w"]),
                                   np.asarray(c_ref["w"]), atol=1e-4)


# ---------------------------------------------------------------------------
# bd-tile autotuning for the per-example conv-grad kernel


def test_pe_conv_bd_autotune():
    bd = kops.pick_bd(64, 16, (32, 32), (30, 30), (3, 3))
    assert 64 % bd == 0
    # working set must fit the budget
    assert 4 * (16 * 32 * 32 + bd * (30 * 30 + 16 * 9)) <= kops.VMEM_BUDGET
    # a tiny budget forces tiling below full D
    small = kops.pick_bd(64, 16, (32, 32), (30, 30), (3, 3), budget=1 << 18)
    assert small < 64 and 64 % small == 0
    # env override wins, rounded down to a divisor of D
    try:
        os.environ["REPRO_PE_CONV_BD"] = "8"
        assert kops.pick_bd(64, 16, (32, 32), (30, 30), (3, 3)) == 8
        os.environ["REPRO_PE_CONV_BD"] = "7"  # not a divisor -> 4
        assert kops.pick_bd(64, 16, (32, 32), (30, 30), (3, 3)) == 4
    finally:
        del os.environ["REPRO_PE_CONV_BD"]


def test_planner_backward_sum_phase_reachable():
    """A local_vjp layer whose per-example-grad stash blows the budget is
    charged the vmapped-VJP premium on its contraction; when it dominates
    the model, the planner routes its sum through one shared weighted
    backward."""
    from repro.core.tapper import LayerMeta

    B, T, D = 8, 128, 256
    metas = {
        "ssm": LayerMeta("local_vjp", ("ssm",), fn=lambda p, x: x),
        "head": LayerMeta("dense", ("head",)),
    }
    cap_shapes = {
        "ssm": {"inputs": (jax.ShapeDtypeStruct((B, T, D), jnp.float32),)},
        "head": {"x": jax.ShapeDtypeStruct((B, 1, 8), jnp.float32)},
    }
    tap_shapes = {
        "ssm": jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        "head": jax.ShapeDtypeStruct((B, 1, 4), jnp.float32),
    }
    params = {"ssm": {"A": jnp.zeros((4096, 4096))},
              "head": {"w": jnp.zeros((8, 4))}}
    plan = costmodel.plan_execution(
        metas, cap_shapes, tap_shapes, lambda: {}, params,
        mem_budget=B * 4096 * 4096 * 4 // 2)  # stash over budget
    assert not plan.layers["ssm"].stash
    assert plan.needs_backward
    sums = {g.path: g.sum_method for g in plan.groups}
    assert sums[("ssm",)] == "backward"
    assert sums[("head",)] != "backward"


def test_executor_backward_sum_phase_exact(toy_model):
    """Force a group onto the weighted-backward sum path and check the
    executor still reproduces the naive clipped sum (and pays the extra
    forward+backward)."""
    import dataclasses

    apply_fn, params, batch = toy_model
    C = 0.05
    plan = costmodel.get_plan(apply_fn, params, batch)
    groups = tuple(
        dataclasses.replace(g, sum_method="backward")
        if g.path == ("head",) else g for g in plan.groups)
    forced = dataclasses.replace(plan, groups=groups, needs_backward=True)
    _, ref, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                                 strategy="naive")
    STATS.reset()
    from repro.core.strategies import planned_clipped_sum
    _, got, _, _ = planned_clipped_sum(apply_fn, params, batch, forced,
                                       l2_clip=C, check=True)
    assert STATS.forwards == 2 and STATS.backwards == 2
    assert tree_maxdiff(got, ref) < TOL


def test_planner_cumulative_stash_budget(toy_model):
    """Stashes live together until the sum phase, so the budget must be
    charged across groups: with a budget big enough for each layer but
    not all of them, later groups fall back to contrib — and the plan
    still executes exactly."""
    apply_fn, params, batch = toy_model
    plan_big = costmodel.get_plan(apply_fn, params, batch)
    stashed = [g for g in plan_big.groups if g.sum_method == "stash"]
    assert len(stashed) >= 2
    per_group = [max(plan_big.layers[n].stash_bytes for n in g.members)
                 for g in stashed]
    budget = int(max(per_group) + min(per_group) / 2)  # fits 1, not all
    plan_small = costmodel.get_plan(apply_fn, params, batch,
                                    mem_budget=budget)
    kinds_small = [g.sum_method for g in plan_small.groups]
    assert "contrib" in kinds_small            # something got flipped
    running = 0.0
    for g in plan_small.groups:
        if g.sum_method == "stash":
            running += max(plan_small.layers[n].stash_bytes
                           for n in g.members)
    assert running <= budget
    from repro.core.strategies import planned_clipped_sum
    C = 0.05
    _, ref, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                                 strategy="naive")
    _, got, _, _ = planned_clipped_sum(apply_fn, params, batch, plan_small,
                                       l2_clip=C, check=True)
    assert tree_maxdiff(got, ref) < TOL


def test_planner_stash_memory_respects_stack():
    """A scanned stack of dense layers multiplies the stashed per-example
    grad scratch; the planner must veto the stash (falling back to the
    layer-at-a-time stream norm or the Gram) instead of holding the whole
    stack."""
    from repro.core.tapper import LayerMeta
    import jax.numpy as jnp

    L, B, T, D = 32, 8, 2048, 1024
    meta = LayerMeta("dense", ("blocks", "fc"), scanned=1)
    cap = {"x": jax.ShapeDtypeStruct((L, B, T, D), jnp.float32)}
    dy = jax.ShapeDtypeStruct((L, B, T, D), jnp.float32)
    budget = 2 * B * D * D * 4  # two layers' worth: per-layer ok, stack not
    lp = costmodel._plan_layer("fc", meta, cap, dy, norm_method="auto",
                               embed_method="auto", conv_norm="auto",
                               mem_budget=budget)
    assert not lp.stash
    # with room for the whole stack, stashing is back on
    lp2 = costmodel._plan_layer("fc", meta, cap, dy, norm_method="auto",
                                embed_method="auto", conv_norm="auto",
                                mem_budget=L * B * D * D * 4)
    assert lp2.stash and lp2.norm_method == "stream"
