"""Sharding-aware DP execution.

Two groups of tests:

* Mesh-aware *planning* (no devices needed — a mesh spec plans for a
  topology this host doesn't have): collective-bytes cost terms flip
  per-layer decisions, the mesh is folded into fingerprints and cache
  keys, and stale plans fail loudly with the offending field named.
* ``multidevice``-marked *execution* equivalence: on a forced 8-device
  host (``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the CI
  multi-device lane), the sharded ``private_step`` must equal the
  single-device engine on the same batch, including the noise (one
  replicated draw, not per-shard).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tree_maxdiff
from repro.core import DPConfig, ExecPlan, PrivacyEngine, costmodel
from repro.optim import adamw_init

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _batch8(batch):
    return jax.tree.map(lambda a: jnp.concatenate([a, a], axis=0), batch)


# ---------------------------------------------------------------------------
# Mesh normalization + planning (device-free)


def test_mesh_axes_normalization():
    assert costmodel.mesh_axes(None) == ()
    assert costmodel.mesh_axes("data:8") == (("data", 8),)
    assert costmodel.mesh_axes("data:4, model:2") == (("data", 4),
                                                      ("model", 2))
    assert costmodel.mesh_axes({"data": 8}) == (("data", 8),)
    assert costmodel.mesh_axes((("pod", 2), ("data", 4))) == (("pod", 2),
                                                              ("data", 4))
    with pytest.raises(ValueError, match="bad mesh spec"):
        costmodel.mesh_axes("data=8")
    assert costmodel.mesh_data_size((("data", 8), ("model", 2))) == 8
    assert costmodel.mesh_data_size((("pod", 2), ("data", 4))) == 8


def test_mesh_flips_planner_decisions(toy_model):
    """The collective-bytes terms must actually change the plan: a stash
    whose per-example grads would cross the ring loses its free sum."""
    apply_fn, params, batch = toy_model
    p0 = costmodel.get_plan(apply_fn, params, batch)
    p8 = costmodel.get_plan(apply_fn, params, batch, mesh="data:8")
    d0 = {n: (lp.norm_method, p0.sum_methods()[n])
          for n, lp in p0.layers.items()}
    d8 = {n: (lp.norm_method, p8.sum_methods()[n])
          for n, lp in p8.layers.items()}
    assert d0 != d8, "mesh-aware costs changed no per-layer decision"
    assert p8.total_coll_bytes > 0
    assert p0.total_coll_bytes == 0
    assert p8.mesh == (("data", 8),)


def test_mesh_explain_has_collective_column(toy_model):
    apply_fn, params, batch = toy_model
    engine = PrivacyEngine(apply_fn, params, batch, mesh="data:8")
    text = engine.explain()
    assert "coll MB" in text
    assert "mesh=data=8" in text
    assert "mesh: data=8" in text
    # and the per-layer column is populated (grad sync is never free)
    plan = engine.plan()
    assert all(lp.coll_bytes > 0 for lp in plan.layers.values()
               if lp.param_bytes > 0)


def test_mesh_in_fingerprint_and_cache_key(toy_model):
    apply_fn, params, batch = toy_model
    fp0 = costmodel.plan_fingerprint(apply_fn, params, batch)
    fp8 = costmodel.plan_fingerprint(apply_fn, params, batch, mesh="data:8")
    fp8b = costmodel.plan_fingerprint(apply_fn, params, batch,
                                      mesh={"data": 8})
    assert fp0 != fp8
    assert fp8 == fp8b          # spec string and axes dict key identically
    p0 = costmodel.get_plan(apply_fn, params, batch)
    p8 = costmodel.get_plan(apply_fn, params, batch, mesh="data:8")
    assert p0.fingerprint == fp0 and p8.fingerprint == fp8


def test_mesh_survives_json_roundtrip(toy_model):
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch, mesh="data:8")
    restored = ExecPlan.from_json(plan.to_json())
    assert restored == plan
    assert tuple(restored.mesh) == (("data", 8),)
    assert restored.batch_sig == plan.batch_sig
    assert restored.total_coll_bytes == plan.total_coll_bytes


# ---------------------------------------------------------------------------
# Stale-plan validation names the offending field


def test_stale_plan_mesh_mismatch_named(toy_model):
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch, mesh="data:8")
    restored = ExecPlan.from_json(plan.to_json())
    with pytest.raises(ValueError,
                       match=r"mesh shape mismatch.*data=8.*data=4"):
        costmodel.check_plan_matches(restored, mesh="data:4")
    with pytest.raises(ValueError,
                       match=r"mesh shape mismatch.*data=8.*\(no mesh\)"):
        costmodel.check_plan_matches(restored, mesh=())


def test_stale_plan_batch_mismatch_named(toy_model):
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch)
    bigger = _batch8(batch)
    with pytest.raises(ValueError, match=r"batch shape mismatch.*4, 3, 12"):
        costmodel.check_plan_matches(
            plan, batch_sig=costmodel._shape_sig(bigger))


def test_stale_plan_fingerprint_mismatch_named(toy_model):
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch)
    with pytest.raises(ValueError,
                       match=rf"fingerprint mismatch.*{plan.fingerprint}"):
        costmodel.check_plan_matches(plan, fingerprint="deadbeefdeadbeef")


def test_engine_rejects_mesh_mismatched_plan_up_front(toy_model):
    """Injecting a deserialized plan built for another topology fails at
    engine construction, before any execution."""
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch, mesh="data:8")
    restored = ExecPlan.from_json(plan.to_json())
    with pytest.raises(ValueError, match="mesh shape mismatch"):
        PrivacyEngine(apply_fn, params, batch, plan=restored)


def test_plan_store_cross_topology_load_fails_loudly(toy_model, tmp_path):
    """A plan store written on one topology, loaded on another: the
    planner refuses to silently re-plan over the stale layout."""
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch, mesh="data:8")
    path = str(tmp_path / "plans.json")
    costmodel.save_plan_store(path, [plan])
    costmodel.clear_plan_cache()
    costmodel.clear_plan_store()
    try:
        costmodel.load_plan_store(path)
        with pytest.raises(ValueError, match="mesh shape mismatch"):
            costmodel.get_plan(apply_fn, params, batch, mesh="data:4")
    finally:
        costmodel.clear_plan_store()
        costmodel.clear_plan_cache()


def test_plan_store_ignores_unrelated_model_with_same_batch(toy_model,
                                                            tmp_path):
    """The cross-topology guard must key on *this* model's fingerprint:
    a stored plan for a different model (or knobs) that merely shares the
    batch shape must not block planning."""
    apply_fn, params, batch = toy_model
    # same model+batch but different planner knobs -> different fingerprint
    other = costmodel.get_plan(apply_fn, params, batch, mesh="data:8",
                               norm_method="gram")
    path = str(tmp_path / "plans.json")
    costmodel.save_plan_store(path, [other])
    costmodel.clear_plan_cache()
    costmodel.clear_plan_store()
    try:
        costmodel.load_plan_store(path)
        plan = costmodel.get_plan(apply_fn, params, batch)   # must not raise
        assert plan.mesh == ()
    finally:
        costmodel.clear_plan_store()
        costmodel.clear_plan_cache()


def test_shared_param_sync_charged_once():
    """Taps sharing one parameter (tied embedding + LM head) sync one
    gradient, not one each: the group's grad-sync bytes are split across
    members instead of double-counted."""
    from repro.configs import get_config
    from repro.models.registry import build_model

    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(lambda k: model.init(k)[0],
                            jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
             "labels": jnp.zeros((8, 16), jnp.int32)}
    plan = costmodel.get_plan(model.apply, params, batch, mesh="data:8")
    tied = [g for g in plan.groups if len(g.members) > 1]
    assert tied, "reduced llama must have a tied embed/head group"
    g = tied[0]
    ring = 2.0 * 7 / 8
    pb = max(plan.layers[n].param_bytes for n in g.members)
    norm_parts = sum(
        (plan.layers[n].stash_bytes if plan.layers[n].stash
         else plan.layers[n].ex_per_dev * 8 * 4) * ring
        for n in g.members)
    got = sum(plan.layers[n].coll_bytes for n in g.members)
    assert got == pytest.approx(norm_parts + pb * ring)   # ONE table sync


def test_batch_sharding_requires_a_data_axis():
    """The executor and the cost model agree on the data-axis vocabulary;
    a model-parallel-only mesh is rejected up front, not with an obscure
    IndexError inside jit setup."""
    from repro.launch.sharding import batch_sharding
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="no data-parallel axis"):
        batch_sharding({"x": jnp.zeros((4, 2))}, mesh)
    # a 'batch'-named axis counts as data parallelism, like the planner
    mesh_b = jax.make_mesh((1,), ("batch",))
    sh = batch_sharding({"x": jnp.zeros((4, 2))}, mesh_b)
    assert jax.tree.leaves(sh)[0].spec == jax.sharding.PartitionSpec("batch")


# ---------------------------------------------------------------------------
# Sharded execution equivalence (the multi-device CI lane)


@pytest.mark.multidevice
@needs_8_devices
def test_sharded_private_step_matches_single_device(toy_model):
    apply_fn, params, batch4 = toy_model
    batch = _batch8(batch4)
    mesh = jax.make_mesh((8,), ("data",))
    dp = DPConfig(l2_clip=0.1)
    e1 = PrivacyEngine(apply_fn, params, batch, dp=dp, lr=1e-2)
    e8 = PrivacyEngine(apply_fn, params, batch, dp=dp, lr=1e-2, mesh=mesh)
    p1, o1 = params, adamw_init(params)
    p8, o8 = params, adamw_init(params)
    for step in range(2):
        p1, o1, l1, _ = e1.private_step(p1, o1, batch)
        p8, o8, l8, _ = e8.private_step(p8, o8, batch)
        assert abs(float(l1) - float(l8)) < 1e-5
    assert tree_maxdiff(p1, p8) < 1e-6


@pytest.mark.multidevice
@needs_8_devices
def test_sharded_noise_is_replicated_not_per_shard(toy_model):
    """With a noise multiplier, the sharded step must add the *same* draw
    on every device (one replicated key), so it still equals the
    single-device noisy step bit-for-bit up to reduction order."""
    apply_fn, params, batch4 = toy_model
    batch = _batch8(batch4)
    mesh = jax.make_mesh((8,), ("data",))
    dp = DPConfig(l2_clip=0.1, noise_multiplier=1.3)
    key = jax.random.key_data(jax.random.PRNGKey(7))
    e1 = PrivacyEngine(apply_fn, params, batch, dp=dp, lr=1e-2)
    e8 = PrivacyEngine(apply_fn, params, batch, dp=dp, lr=1e-2, mesh=mesh)
    p1, _, _, _ = e1.private_step(params, adamw_init(params), batch, key)
    p8, _, _, _ = e8.private_step(params, adamw_init(params), batch, key)
    assert tree_maxdiff(p1, p8) < 1e-6


@pytest.mark.multidevice
@needs_8_devices
def test_engine_rejects_indivisible_batch_up_front(toy_model):
    """A live mesh whose data degree does not divide the batch fails at
    engine construction with a named error, not inside XLA."""
    apply_fn, params, batch4 = toy_model   # B=4 on an 8-way data mesh
    mesh = jax.make_mesh((8,), ("data",))
    with pytest.raises(ValueError, match="not divisible.*degree 8"):
        PrivacyEngine(apply_fn, params, batch4,
                      dp=DPConfig(l2_clip=0.1), mesh=mesh)


@pytest.mark.multidevice
@needs_8_devices
def test_sharded_step_places_batch_on_data_axis(toy_model):
    apply_fn, params, batch4 = toy_model
    batch = _batch8(batch4)
    mesh = jax.make_mesh((8,), ("data",))
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(l2_clip=0.1), mesh=mesh)
    p, _, _, _ = engine.private_step(params, adamw_init(params), batch)
    # outputs are replicated; the jitted step carries explicit shardings
    for leaf in jax.tree.leaves(p):
        assert leaf.sharding.is_fully_replicated
    # the plan the engine executed is the mesh-keyed one
    assert tuple(engine.plan().mesh) == (("data", 8),)


@pytest.mark.multidevice
@needs_8_devices
def test_live_mesh_and_spec_plan_identically(toy_model):
    """A live Mesh and its spec string produce the same fingerprint, so
    plans serialized on a devices-attached host load on a planning-only
    host and vice versa."""
    apply_fn, params, batch4 = toy_model
    batch = _batch8(batch4)
    mesh = jax.make_mesh((8,), ("data",))
    fp_live = costmodel.plan_fingerprint(apply_fn, params, batch, mesh=mesh)
    fp_spec = costmodel.plan_fingerprint(apply_fn, params, batch,
                                         mesh="data:8")
    assert fp_live == fp_spec


# ---------------------------------------------------------------------------
# 2D (data x model) meshes: per-axis pricing + tensor-sharded execution


def test_mesh_axes_drop_unit_axes():
    """Size-1 axes execute identically to their absence; they must not
    make a stored plan fail safe spuriously."""
    assert costmodel.mesh_axes("data:8,model:1") == (("data", 8),)
    assert costmodel.mesh_axes((("data", 8), ("model", 1))) == (("data", 8),)
    assert costmodel.mesh_axes({"data": 8, "model": 1}) == (("data", 8),)
    assert costmodel.mesh_axes("data:1") == ()


def test_check_plan_matches_ignores_unit_axes(toy_model):
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch, mesh="data:8")
    # identical topology spelled with a trivial model axis: no error
    costmodel.check_plan_matches(plan, mesh="data:8,model:1")
    with pytest.raises(ValueError, match="mesh shape mismatch"):
        costmodel.check_plan_matches(plan, mesh="data:8,model:2")


def test_mesh_model_axis_helpers():
    axes = (("data", 4), ("model", 2))
    assert costmodel.mesh_data_axes(axes) == (("data", 4),)
    assert costmodel.mesh_model_axes(axes) == (("model", 2),)
    assert costmodel.mesh_model_size(axes) == 2
    assert costmodel.mesh_model_axes((("pod", 2), ("data", 4))) == ()


def test_axisless_pricing_warns_on_multi_axis_calibration():
    import warnings as _w
    from repro import calibrate
    c = calibrate.injected(
        mesh="data:4,model:2", flops_per_second=1e12,
        collective_bytes_per_second={"data": 16e9, "model": 2e9})
    with pytest.warns(calibrate.CalibrationAxisFallbackWarning):
        v = c.collective_flops_per_byte()
    assert v == pytest.approx(1e12 / 2e9)        # slowest axis
    assert c.collective_flops_per_byte("data") == pytest.approx(1e12 / 16e9)
    # legacy single-axis calibrations keep the silent fallback
    c1 = calibrate.injected(mesh="data:8", flops_per_second=1e12,
                            collective_bytes_per_second=16e9)
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert c1.collective_flops_per_byte() == pytest.approx(1e12 / 16e9)


def test_2d_per_axis_collective_pricing_hand_computed():
    """Acceptance: with data/model bandwidths 8x apart, the planned
    collective cost of tensor-sharded llama32_1b layers is the per-axis
    sum — scalar norms priced on the data ring, partial-Gram psums on
    the model ring — never the slowest-axis scalar, and planning never
    takes the axis-less fallback."""
    import dataclasses as _dc
    import warnings as _w
    from repro import calibrate
    from repro.configs import get_config
    from repro.models.registry import build_model

    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(lambda k: model.init(k)[0],
                            jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
             "labels": jnp.zeros((8, 16), jnp.int32)}
    calib = calibrate.injected(
        mesh="data:4,model:2", flops_per_second=1e12,
        collective_bytes_per_second={"data": 16e9, "model": 2e9})
    with _w.catch_warnings():
        _w.simplefilter("error", calibrate.CalibrationAxisFallbackWarning)
        plan = costmodel.get_plan(model.apply, params, batch,
                                  mesh="data:4,model:2", calibration=calib)
    sharded = {n: lp for n, lp in plan.layers.items()
               if lp.model_shards > 1}
    assert sharded, "no tensor-sharded layer planned for llama32_1b"
    d = 4                 # data-parallel degree: B = ex_per_dev * d
    ring_d = 2.0 * (4 - 1) / 4              # data:4 ring factor
    ring_m = 2.0 * (2 - 1) / 2              # model:2 ring factor
    by_group = {m: g for g in plan.groups for m in g.members}
    for name, lp in sharded.items():
        g = by_group[name]
        group_pb = max(plan.layers[m].param_bytes for m in g.members)
        sync = group_pb * (2.0 if g.sum_method == "backward" else 1.0) \
            / len(g.members)
        norm_bytes = (lp.stash_bytes if lp.stash
                      else lp.ex_per_dev * d * 4)
        want = {"data": (norm_bytes + sync) * ring_d,
                "model": lp.ex_per_dev * d * 4 * ring_m}
        assert dict(lp.coll_bytes_by_axis) == pytest.approx(want), name
        assert lp.coll_bytes == pytest.approx(sum(want.values())), name
    # the predicted cost prices each axis at its own bandwidth
    cc = costmodel.resolve_cost_constants(calib, plan.mesh)
    assert cc.coll_price("data") == pytest.approx(1e12 / 16e9)
    assert cc.coll_price("model") == pytest.approx(1e12 / 2e9)
    no_coll = _dc.replace(plan, total_coll_bytes=0.0,
                          total_coll_bytes_by_axis=())
    coll_flops = costmodel.predicted_step_flops(plan, cc) \
        - costmodel.predicted_step_flops(no_coll, cc)
    want_flops = sum(cc.coll_price(a) * b
                     for a, b in plan.total_coll_bytes_by_axis)
    assert coll_flops == pytest.approx(want_flops)
    # slowest-axis pricing (the old bug) would overcharge the data traffic
    slowest_flops = cc.collective_flops_per_byte * plan.total_coll_bytes
    assert want_flops < slowest_flops


def test_2d_plan_payload_roundtrips_per_axis_bytes(toy_model):
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch,
                              mesh="data:4,model:2")
    assert plan.total_coll_bytes_by_axis
    assert dict(plan.total_coll_bytes_by_axis)["data"] > 0
    restored = ExecPlan.from_json(plan.to_json())
    assert restored == plan
    assert restored.total_coll_bytes_by_axis == plan.total_coll_bytes_by_axis
    for n, lp in plan.layers.items():
        assert restored.layers[n].coll_bytes_by_axis == lp.coll_bytes_by_axis
        assert restored.layers[n].model_shards == lp.model_shards
    # explain() surfaces the per-axis breakdown
    assert "per axis:" in plan.explain()


def test_planning_only_2d_mesh_never_auto_measures(toy_model, monkeypatch):
    """A mesh *spec* plans for a topology this host doesn't have — it
    must not try to measure it; 'analytic' is the explicit opt-out on a
    live mesh too."""
    from repro import calibrate

    def boom(*a, **k):
        raise AssertionError("measure() ran for a planning-only engine")

    monkeypatch.setattr(calibrate, "measure", boom)
    apply_fn, params, batch = toy_model
    eng = PrivacyEngine(apply_fn, params, batch, mesh="data:4,model:2")
    assert eng.calibration is None
    eng2 = PrivacyEngine(apply_fn, params, batch, mesh="data:4,model:2",
                         calibration="analytic")
    assert eng2.calibration is None


@pytest.mark.multidevice
@needs_8_devices
def test_2d_engine_auto_calibrates_by_default(toy_model, monkeypatch):
    """PR-8 follow-up: a fresh engine on a live 2D mesh must not price
    the model axis from ANALYTIC_FALLBACK — absent a registered
    calibration it measures once per (hardware, mesh) per process."""
    from repro import calibrate

    apply_fn, params, batch4 = toy_model
    batch = _batch8(batch4)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    calls = []
    fake = calibrate.injected(
        mesh="data:4,model:2",
        collective_bytes_per_second={"data": 8e9, "model": 2e9})

    def fake_measure(mesh=None, quick=True):
        calls.append(costmodel.mesh_axes(mesh))
        return fake

    monkeypatch.setattr(calibrate, "measure", fake_measure)
    calibrate.clear_registry()
    try:
        costmodel.clear_plan_cache()
        eng = PrivacyEngine(apply_fn, params, batch, mesh=mesh)
        assert eng.calibration is fake
        assert calls == [(("data", 4), ("model", 2))]
        # second engine: registry hit, no re-measure
        eng2 = PrivacyEngine(apply_fn, params, batch, mesh=mesh)
        assert eng2.calibration is fake and len(calls) == 1
        # explicit opt-out
        eng3 = PrivacyEngine(apply_fn, params, batch, mesh=mesh,
                             calibration="analytic")
        assert eng3.calibration is None
    finally:
        calibrate.clear_registry()
        costmodel.clear_plan_cache()


@pytest.mark.multidevice
@needs_8_devices
@pytest.mark.parametrize("arch", ("alexnet", "llama3.2-1b"))
def test_sharded_2d_private_step_matches_single_device(arch):
    """Acceptance: private_step on data:4,model:2 with tensor-sharded
    params equals the single-device reference — noise included (the one
    replicated key; partitionable threefry makes the sharded draw
    value-identical) — for a CNN and llama32_1b."""
    from repro.configs import get_config
    from repro.launch.train import make_batch_fn
    from repro.models.registry import build_model
    from repro.optim import sgdm_init

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    batch_fn = make_batch_fn(cfg, 8, 32)
    params, axes = model.init(jax.random.PRNGKey(0))
    dp = DPConfig(l2_clip=1.0, noise_multiplier=0.8)
    costmodel.clear_plan_cache()
    e1 = PrivacyEngine(model.apply, params, batch_fn(0), dp=dp,
                       optimizer="sgdm", lr=1e-2, run_seed=7,
                       sampling_rate=0.01, calibration="analytic")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    costmodel.clear_plan_cache()
    e2 = PrivacyEngine(model.apply, params, batch_fn(0), dp=dp,
                       optimizer="sgdm", lr=1e-2, mesh=mesh,
                       param_axes=axes, run_seed=7, sampling_rate=0.01,
                       calibration="analytic")
    p1, o1 = params, sgdm_init(params)
    p2, o2 = params, sgdm_init(params)
    for step in range(2):
        p1, o1, l1, _ = e1.private_step(p1, o1, batch_fn(step), step=step)
        p2, o2, l2, _ = e2.private_step(p2, o2, batch_fn(step), step=step)
        assert abs(float(l1) - float(l2)) < 1e-5
    assert tree_maxdiff(p1, p2) < 1e-6
    # identical accountant ledgers
    assert e1.accountant.steps == e2.accountant.steps
    assert e1.epsilon(1e-5) == e2.epsilon(1e-5)
    # params really partitioned over the model axis
    assert any(not leaf.sharding.is_fully_replicated
               for leaf in jax.tree.leaves(p2))
    # all analysis lanes pass on the 2D mesh
    report = e2.verify()
    assert not report.errors, report.errors
    assert "partitioned over model" in report.checked["sharding"]


@pytest.mark.multidevice
@needs_8_devices
def test_sharded_2d_custom_optimizer_state_inherits_param_layout():
    """Regression: a custom optimizer callable's state used to stay
    replicated on a tensor-sharded mesh (the sharding table only knew
    adamw/sgdm by name), silently forfeiting the ZeRO-style moment
    partitioning.  The engine now derives the layout from the recorded
    state pytree — moment-like leaves (shaped like a param whose layout
    is unambiguous) inherit the param sharding, scalars stay replicated
    — and the step still matches the single-device reference."""
    from repro.configs import get_config
    from repro.launch.sharding import param_sharding
    from repro.launch.train import make_batch_fn
    from repro.models.registry import build_model

    def momentum(grad, opt, params, *, lr, weight_decay):
        mom = jax.tree.map(lambda m, g: 0.9 * m + g, opt["mom"], grad)
        new = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        return new, {"mom": mom, "step": opt["step"] + 1}

    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    batch_fn = make_batch_fn(cfg, 8, 32)
    params, axes = model.init(jax.random.PRNGKey(0))
    dp = DPConfig(l2_clip=1.0, noise_multiplier=0.8)

    def opt0():
        return {"mom": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    costmodel.clear_plan_cache()
    e1 = PrivacyEngine(model.apply, params, batch_fn(0), dp=dp,
                       optimizer=momentum, lr=1e-2, run_seed=7,
                       calibration="analytic")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    costmodel.clear_plan_cache()
    e2 = PrivacyEngine(model.apply, params, batch_fn(0), dp=dp,
                       optimizer=momentum, lr=1e-2, mesh=mesh,
                       param_axes=axes, run_seed=7, calibration="analytic")
    p1, o1 = params, opt0()
    p2, o2 = params, opt0()
    for step in range(2):
        p1, o1, l1, _ = e1.private_step(p1, o1, batch_fn(step), step=step)
        p2, o2, l2, _ = e2.private_step(p2, o2, batch_fn(step), step=step)
        assert abs(float(l1) - float(l2)) < 1e-5
    assert tree_maxdiff(p1, p2) < 1e-6
    assert tree_maxdiff(o1["mom"], o2["mom"]) < 1e-6
    # the regression: moment leaves are actually partitioned now
    assert any(not leaf.sharding.is_fully_replicated
               for leaf in jax.tree.leaves(o2["mom"])), \
        "custom optimizer moments stayed replicated"
    # ... and mirror the param layout wherever it is unambiguous
    in_sh, _ = e2._step_shardings()
    psh = param_sharding(axes, mesh, shapes_tree=e2._params_spec)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    for got, want in zip(jax.tree.leaves(in_sh[1]["mom"]),
                         jax.tree.leaves(psh)):
        assert got == want or got == repl
    assert in_sh[1]["step"] == repl
