"""Runtime layer: straggler monitor, chaos/restart orchestration, elastic
degree computation, optimizer convergence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import adamw_init, adamw_update, sgdm_init, sgdm_update, \
    cosine_schedule
from repro.runtime import (ChaosMonkey, StepMonitor, WorkerFailure,
                           backoff_delay, elastic_data_degree,
                           elastic_mesh_axes, run_with_restarts)


def test_monitor_flags_stragglers():
    mon = StepMonitor(alpha=0.5, threshold=2.0)
    for s in range(10):
        mon.observe(s, 0.1)
    mon.observe(10, 1.0)
    assert mon.stragglers and mon.stragglers[-1][0] == 10
    assert mon.is_straggler(1.0)
    assert not mon.is_straggler(0.11)


def test_monitor_state_survives_restart():
    """The checkpointed monitor restores EMA + straggler history, so the
    first post-restore step is judged against the pre-kill baseline
    instead of re-seeding the EMA."""
    mon = StepMonitor(alpha=0.5, threshold=2.0)
    for s in range(10):
        mon.observe(s, 0.1)
    mon.observe(10, 1.0)
    fresh = StepMonitor.from_state(mon.state_dict())
    assert fresh.ema == mon.ema
    assert fresh.stragglers == mon.stragglers
    assert fresh.alpha == 0.5 and fresh.threshold == 2.0
    # a straggler right after restore is flagged, not absorbed as baseline
    fresh.observe(11, 1.0)
    assert fresh.stragglers[-1] == (11, 1.0)
    # round-trips through JSON (the checkpoint meta sidecar)
    import json
    assert StepMonitor.from_state(
        json.loads(json.dumps(mon.state_dict()))).ema == mon.ema


def test_monitor_state_roundtrip_cold():
    """A never-observed monitor (ema=None) serializes too."""
    mon = StepMonitor.from_state(StepMonitor().state_dict())
    assert mon.ema is None and mon.stragglers == []


def test_chaos_and_restarts():
    chaos = ChaosMonkey(fail_at_steps=[3, 7])
    state = {"restarts": []}

    def segment(restart):
        state["restarts"].append(restart)
        for step in range(10):
            chaos.maybe_fail(step)
        return "done"

    out, restarts = run_with_restarts(segment, max_restarts=5)
    assert out == "done"
    assert restarts == 2
    assert chaos.tripped == 2


def test_restart_budget_exhausted():
    chaos = ChaosMonkey(p=1.0)

    def segment(restart):
        chaos.maybe_fail(0)

    with pytest.raises(WorkerFailure):
        run_with_restarts(segment, max_restarts=2)


def test_configurable_catch_set():
    """Only exceptions in ``catch`` trigger a restart; anything else is a
    hard kill and propagates immediately."""
    calls = {"n": 0}

    def flaky(restart):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("nfs blipped")
        return "ok"

    out, restarts = run_with_restarts(flaky, catch=(OSError,))
    assert out == "ok" and restarts == 1
    calls["n"] = 0
    with pytest.raises(OSError):
        run_with_restarts(flaky, catch=(WorkerFailure,), max_restarts=5)
    assert calls["n"] == 1  # no restart attempted


def test_backoff_is_exponential_jittered_capped():
    delays = [backoff_delay(a, base_s=1.0, cap_s=8.0, jitter=0.0)
              for a in (1, 2, 3, 4, 5)]
    assert delays == [1.0, 2.0, 4.0, 8.0, 8.0]   # doubles, then caps
    assert backoff_delay(3, base_s=0.0) == 0.0   # disabled
    import random
    rng = random.Random(0)
    jittered = [backoff_delay(2, base_s=1.0, jitter=0.5, rng=rng)
                for _ in range(100)]
    assert all(2.0 <= d <= 3.0 for d in jittered)
    assert len(set(jittered)) > 1                # actually jittered


def test_run_with_restarts_sleeps_with_backoff():
    slept = []
    chaos = ChaosMonkey(fail_at_steps=[0, 1, 2])
    state = {"step": 0}

    def segment(restart):
        chaos.maybe_fail(state["step"])
        state["step"] += 1
        if state["step"] < 3:
            raise WorkerFailure("again")
        return "done"

    out, _ = run_with_restarts(segment, max_restarts=10, backoff_s=0.01,
                               jitter=0.0, sleep=slept.append)
    assert out == "done"
    assert slept[:3] == [0.01, 0.02, 0.04]       # exponential


def test_restart_window_budget():
    """Failures older than the window don't count against the budget: a
    long-lived run survives more than max_restarts lifetime faults as
    long as they're spread out."""
    t = {"now": 0.0}

    def segment(restart):
        t["now"] += 100.0             # 100s of healthy progress per life
        if restart < 5:
            raise WorkerFailure(f"fault {restart}")
        return "done"

    # budget 2 restarts / 150s window: 5 spread-out faults survive ...
    out, restarts = run_with_restarts(
        segment, max_restarts=2, restart_window_s=150.0,
        clock=lambda: t["now"], sleep=lambda s: None)
    assert out == "done" and restarts == 5
    # ... but the same faults in one burst exhaust it
    t["now"] = 0.0

    def bursty(restart):
        t["now"] += 1.0
        raise WorkerFailure("crash loop")

    with pytest.raises(WorkerFailure):
        run_with_restarts(bursty, max_restarts=2, restart_window_s=150.0,
                          clock=lambda: t["now"], sleep=lambda s: None)


def test_chaos_monkey_custom_exception():
    class Preemption(SystemExit):
        pass

    chaos = ChaosMonkey(fail_at_steps=[2], exc=Preemption)
    chaos.maybe_fail(1)
    with pytest.raises(Preemption):
        chaos.maybe_fail(2)
    # seeded probabilistic chaos replays identically
    a = ChaosMonkey(p=0.5, seed=13)
    b = ChaosMonkey(p=0.5, seed=13)
    for step in range(50):
        fa = fb = False
        try:
            a.maybe_fail(step)
        except WorkerFailure:
            fa = True
        try:
            b.maybe_fail(step)
        except WorkerFailure:
            fb = True
        assert fa == fb
    assert a.tripped > 0


def test_elastic_degree():
    assert elastic_data_degree(256, 16, 256) == 16
    assert elastic_data_degree(240, 16, 256) == 8  # 15 doesn't divide 256
    assert elastic_data_degree(32, 16, 64) == 2
    with pytest.raises(ValueError):
        elastic_data_degree(8, 16, 64)


def test_elastic_degree_indivisible_batch():
    # prime global batch: only degree 1 (or the batch itself) divides it
    assert elastic_data_degree(8, 1, 7) == 7
    assert elastic_data_degree(6, 1, 7) == 1
    assert elastic_data_degree(8, 1, 1) == 1
    # model_par consumes devices before the data split
    assert elastic_data_degree(12, 4, 9) == 3
    assert elastic_data_degree(16, 16, 64) == 1   # exactly model_par left


def test_elastic_degree_microbatch_interaction():
    # the data degree must divide the *per-microbatch* global batch
    assert elastic_data_degree(8, 1, 64, microbatches=1) == 8
    assert elastic_data_degree(8, 1, 64, microbatches=8) == 8
    assert elastic_data_degree(8, 1, 64, microbatches=16) == 4
    assert elastic_data_degree(8, 1, 24, microbatches=2) == 6
    with pytest.raises(ValueError):
        elastic_data_degree(2, 4, 64, microbatches=2)


def test_elastic_mesh_axes():
    # data-only mesh shrinks to the surviving feasible degree
    assert elastic_mesh_axes((("data", 8),), 4, 8) == (("data", 4),)
    assert elastic_mesh_axes((("data", 8),), 8, 8) == (("data", 8),)
    # model parallelism is preserved, data absorbs the loss
    assert elastic_mesh_axes((("data", 4), ("model", 2)), 4, 8) == \
        (("data", 2), ("model", 2))
    # degree-1 data axis drops away (resume unsharded)
    assert elastic_mesh_axes((("data", 8),), 1, 8) == ()
    assert elastic_mesh_axes((("data", 2), ("model", 2)), 2, 8) == \
        (("model", 2),)
    # multiple data axes collapse into one at the first data position
    assert elastic_mesh_axes((("pod", 2), ("data", 4), ("model", 2)),
                             8, 16) == (("pod", 4), ("model", 2))
    # unsharded checkpoints stay unsharded
    assert elastic_mesh_axes((), 8, 64) == ()
    # fewer devices than model_par is not elastically recoverable
    with pytest.raises(ValueError):
        elastic_mesh_axes((("data", 4), ("model", 4)), 2, 8)


def test_adamw_converges():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}  # grad of ||w||^2
        params, opt = adamw_update(g, opt, params, lr=0.1)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert int(opt["step"]) == 200


def test_sgdm_converges():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = sgdm_init(params)
    for _ in range(100):
        g = {"w": 2 * params["w"]}
        params, opt = sgdm_update(g, opt, params, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule():
    lrs = [float(cosine_schedule(jnp.asarray(s), warmup=10, total=100,
                                 peak=1.0)) for s in (0, 9, 10, 55, 99)]
    assert lrs[0] < lrs[1] <= 1.0
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]
