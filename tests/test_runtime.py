"""Runtime layer: straggler monitor, chaos/restart orchestration, elastic
degree computation, optimizer convergence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import adamw_init, adamw_update, sgdm_init, sgdm_update, \
    cosine_schedule
from repro.runtime import (ChaosMonkey, StepMonitor, WorkerFailure,
                           elastic_data_degree, run_with_restarts)


def test_monitor_flags_stragglers():
    mon = StepMonitor(alpha=0.5, threshold=2.0)
    for s in range(10):
        mon.observe(s, 0.1)
    mon.observe(10, 1.0)
    assert mon.stragglers and mon.stragglers[-1][0] == 10
    assert mon.is_straggler(1.0)
    assert not mon.is_straggler(0.11)


def test_chaos_and_restarts():
    chaos = ChaosMonkey(fail_at_steps=[3, 7])
    state = {"restarts": []}

    def segment(restart):
        state["restarts"].append(restart)
        for step in range(10):
            chaos.maybe_fail(step)
        return "done"

    out, restarts = run_with_restarts(segment, max_restarts=5)
    assert out == "done"
    assert restarts == 2
    assert chaos.tripped == 2


def test_restart_budget_exhausted():
    chaos = ChaosMonkey(p=1.0)

    def segment(restart):
        chaos.maybe_fail(0)

    with pytest.raises(WorkerFailure):
        run_with_restarts(segment, max_restarts=2)


def test_elastic_degree():
    assert elastic_data_degree(256, 16, 256) == 16
    assert elastic_data_degree(240, 16, 256) == 8  # 15 doesn't divide 256
    assert elastic_data_degree(32, 16, 64) == 2
    with pytest.raises(ValueError):
        elastic_data_degree(8, 16, 64)


def test_adamw_converges():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}  # grad of ||w||^2
        params, opt = adamw_update(g, opt, params, lr=0.1)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert int(opt["step"]) == 200


def test_sgdm_converges():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = sgdm_init(params)
    for _ in range(100):
        g = {"w": 2 * params["w"]}
        params, opt = sgdm_update(g, opt, params, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule():
    lrs = [float(cosine_schedule(jnp.asarray(s), warmup=10, total=100,
                                 peak=1.0)) for s in (0, 9, 10, 55, 99)]
    assert lrs[0] < lrs[1] <= 1.0
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]
