"""DP-SGD mechanics: clipping semantics, microbatch equivalence, noise
statistics."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tree_maxdiff
from repro.core import DPConfig, clip_coefficients
from repro.core.clipping import add_noise, dp_gradient


def test_clip_coefficients():
    n2 = jnp.array([0.25, 4.0, 100.0])
    c = clip_coefficients(n2, l2_clip=1.0)
    np.testing.assert_allclose(c, [1.0, 0.5, 0.1], rtol=1e-5)


def test_microbatch_equivalence(toy_model):
    apply_fn, params, batch = toy_model
    base = DPConfig(l2_clip=0.1, noise_multiplier=0.0, strategy="ghost")
    loss1, g1, _ = dp_gradient(apply_fn, params, batch, cfg=base)
    loss2, g2, _ = dp_gradient(
        apply_fn, params, batch,
        cfg=DPConfig(l2_clip=0.1, noise_multiplier=0.0, strategy="ghost",
                     microbatches=2))
    assert abs(float(loss1) - float(loss2)) < 1e-6
    assert tree_maxdiff(g1, g2) < 1e-6


def test_noise_statistics():
    grad = {"w": jnp.zeros((200, 200))}
    sigma, C = 1.5, 2.0
    noisy = add_noise(grad, jax.random.PRNGKey(0), sigma, C)
    flat = np.asarray(noisy["w"]).ravel()
    assert abs(flat.mean()) < 0.05 * sigma * C
    np.testing.assert_allclose(flat.std(), sigma * C, rtol=0.05)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 0.05),
                                        (jnp.bfloat16, 0.05),
                                        (jnp.float16, 0.05)])
def test_noise_variance_per_dtype(dtype, rtol):
    """Noise variance is pinned at (σC)² for every grad dtype: the noise is
    generated in float32 and added *before* the cast back, so low-precision
    grads never quantize σ·ξ on its own."""
    grad = {"w": jnp.zeros((256, 256), dtype)}
    sigma, C = 1.5, 2.0
    noisy = add_noise(grad, jax.random.PRNGKey(3), sigma, C)
    assert noisy["w"].dtype == dtype
    flat = np.asarray(noisy["w"], np.float64).ravel()
    np.testing.assert_allclose(flat.std(), sigma * C, rtol=rtol)
    assert abs(flat.mean()) < 0.05 * sigma * C


def test_noise_added_in_float32_before_cast():
    """Order of operations pinned: result == cast(f32(g) + σC·ξ_f32), not
    g + cast(σC·ξ) — distinguishable because bf16 rounds signal+noise once
    instead of rounding the noise and the sum separately."""
    rng = np.random.RandomState(0)
    g32 = jnp.array(rng.randn(64, 64), jnp.float32)
    grad = {"w": g32.astype(jnp.bfloat16)}
    sigma, C = 0.9, 1.1
    key = jax.random.PRNGKey(7)
    noisy = add_noise(grad, key, sigma, C)
    (k,) = jax.random.split(key, 1)
    xi = jax.random.normal(k, (64, 64), jnp.float32)
    want = (grad["w"].astype(jnp.float32)
            + sigma * C * xi).astype(jnp.bfloat16)
    assert bool(jnp.all(noisy["w"] == want))


def test_noise_deterministic_in_key():
    grad = {"w": jnp.zeros((8, 8))}
    a = add_noise(grad, jax.random.PRNGKey(7), 1.0, 1.0)
    b = add_noise(grad, jax.random.PRNGKey(7), 1.0, 1.0)
    c = add_noise(grad, jax.random.PRNGKey(8), 1.0, 1.0)
    assert tree_maxdiff(a, b) == 0.0
    assert tree_maxdiff(a, c) > 0.0


def test_dp_gradient_denominator(toy_model):
    apply_fn, params, batch = toy_model
    B = batch["label"].shape[0]
    cfg = DPConfig(l2_clip=1e9, noise_multiplier=0.0, strategy="ghost")
    _, g_dp, _ = dp_gradient(apply_fn, params, batch, cfg=cfg)
    # with a huge clip bound, DP grad == plain mean gradient
    from repro.core.clipping import non_dp_gradient
    _, g_ref = non_dp_gradient(apply_fn, params, batch)
    assert tree_maxdiff(g_dp, g_ref) < 2e-6
