"""Measured-cost calibration: the differential proof that the planner
trusts the hardware, not constants.

Five groups:

* **Round-trip** — a :class:`Calibration` survives the JSON file format
  and the plan store bit-identically (same digest, same payload), and a
  loading process resolves a calibrated plan with no model probe.
* **Plan flip** — a synthetic calibration (``injected``) with a fast
  measured wire flips the ``alexnet@data:8`` plan the analytic constants
  refuse (conv0's stash comes back), while a slow measured wire keeps it
  off and :func:`costmodel.planner_verdict` proves unsharded right —
  the planner either fixes the plan or proves the fixed-constant
  "regression" was priced fiction.
* **Mispredict loop** — feeding a step time that diverges from the
  calibrated prediction beyond the threshold triggers *exactly one*
  re-plan, and the re-planned run's params, optimizer state, and
  accountant ledger are bit-identical to an undisturbed run (the
  test_resume_equivalence.py differential pattern): re-planning is a
  performance decision, never a semantics change.
* **Fail-safe** — absent or corrupt calibration degrades to the analytic
  constants with a named :class:`CalibrationFallbackWarning`, never a
  crash; stale constants fail safe because the calibration digest is
  folded into plan fingerprints and named by ``check_plan_matches``.
* **Mutation harness** — the test_dpcheck.py pattern: each test tampers
  a persisted blob (wrong hardware signature, wrong mesh, truncated
  payload, NaN bandwidth, missing field, foreign format) and asserts the
  *named* rejection.  A loader that accepts any of these plans against
  garbage bandwidths.
"""
import json
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import calibrate
from repro.core import DPConfig, PrivacyAccountant, PrivacyEngine, costmodel
from repro.kernels import ops as kops
from repro.optim import adamw_init
from repro.runtime.monitor import StepMonitor

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

RUN_SEED = 7
NOISE = 0.9
STEPS = 5


@pytest.fixture(autouse=True)
def _fresh_calibration_state():
    # Registered calibrations are process-global and folded into plan
    # fingerprints; leakage across tests would silently re-price every
    # subsequent plan.
    calibrate.clear_registry()
    costmodel.clear_plan_cache()
    costmodel.clear_plan_store()
    yield
    calibrate.clear_registry()
    costmodel.clear_plan_cache()
    costmodel.clear_plan_store()


def _bitwise_equal(a, b):
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _batch_fn(batch):
    def fn(step):
        return jax.tree.map(lambda a: jnp.roll(a, step, axis=0), batch)
    return fn


def _engine(toy, *, calibration=None, mesh=None, batch=None,
            threshold=0.5, monitor=None):
    apply_fn, params, batch0 = toy
    dp = DPConfig(l2_clip=0.1, noise_multiplier=NOISE)
    acct = PrivacyAccountant(sampling_rate=1 / 128, noise_multiplier=NOISE)
    return PrivacyEngine(apply_fn, params,
                         batch0 if batch is None else batch, dp=dp,
                         lr=1e-2, accountant=acct, run_seed=RUN_SEED,
                         mesh=mesh, calibration=calibration,
                         mispredict_threshold=threshold, monitor=monitor)


def _drive(engine, params0, batch_fn, steps=STEPS, feed_seconds=None):
    """Step to ``steps`` on the deterministic noise stream, optionally
    feeding a fixed measured step time into the mispredict loop."""
    params, opt = params0, adamw_init(params0)
    engine.accountant.reset()
    for step in range(steps):
        params, opt, _, _ = engine.private_step(params, opt,
                                                batch_fn(step), step=step)
        if feed_seconds is not None:
            engine.observe_step_time(feed_seconds, step=step)
    return params, opt


# ---------------------------------------------------------------------------
# Round-trip: file format and plan store, bit-identical.


def test_calibration_file_round_trip_bit_identical(tmp_path):
    calib = calibrate.injected(
        mesh="data:2", collective_bytes_per_second=3.5e9,
        kernels={"pe_conv_grad": {"vmem_budget": 1 << 20, "bd": 16}})
    path = str(tmp_path / "c.json")
    calibrate.save_calibration(path, calib)
    got = calibrate.load_calibration(path, expect_mesh="data:2")
    assert got == calib                      # every field, bit-identical
    assert got.digest() == calib.digest()
    # digest is content identity: it ignores the measurement timestamp
    import dataclasses
    assert dataclasses.replace(calib, measured_at=0.0).digest() \
        == calib.digest()


def test_plan_store_round_trips_calibration(toy_model, tmp_path):
    apply_fn, params, batch = toy_model
    calib = calibrate.injected()
    eng = _engine(toy_model, calibration=calib)
    plan = eng.plan()
    assert plan.calibration == calib.digest()
    path = str(tmp_path / "plans.json")
    eng.save_plan(path)

    # a fresh process: nothing registered, nothing cached
    calibrate.clear_registry()
    costmodel.clear_plan_cache()
    costmodel.clear_plan_store()
    assert costmodel.load_plan_store(path) >= 1
    # the persisted calibration came back bit-identically and registered
    assert calibrate.lookup(()) == calib
    # a fresh engine resolves the stored plan by fingerprint — same plan,
    # bit-identical payload, no re-probe needed
    eng2 = _engine(toy_model)
    assert eng2.calibration == calib
    assert eng2.plan().to_payload() == plan.to_payload()


def test_store_written_under_calibration_misses_analytic_process(
        toy_model, tmp_path):
    """The fail-safe direction: a store written under measured constants
    does not resolve for a process planning under *different* constants —
    the digest is folded into the fingerprint, so stale constants miss
    (and re-plan) instead of silently executing a stale costing."""
    apply_fn, params, batch = toy_model
    calib = calibrate.injected(flops_per_second=2e12)
    fp_cal = costmodel.plan_fingerprint(apply_fn, params, batch,
                                        calibration=calib)
    fp_analytic = costmodel.plan_fingerprint(apply_fn, params, batch)
    other = calibrate.injected(flops_per_second=3e12)
    fp_other = costmodel.plan_fingerprint(apply_fn, params, batch,
                                          calibration=other)
    assert len({fp_cal, fp_analytic, fp_other}) == 3


# ---------------------------------------------------------------------------
# The plan flip: injected measurements change what the planner builds.


@pytest.fixture(scope="module")
def alexnet():
    from repro.configs import get_config
    from repro.models.registry import build_model
    cfg = get_config("alexnet").replace(img_size=64, n_classes=10)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = {"img": jnp.array(rng.randn(8, 3, 64, 64), jnp.float32),
             "label": jnp.array(rng.randint(0, 10, (8,)))}
    return model, params, batch


def test_injected_calibration_flips_alexnet_data8_plan(alexnet):
    """The BENCH_strategies.json ``alexnet@data:8`` lane, reproduced with
    synthetic measurements: under the analytic wire constant the mesh
    plan withholds conv0's stash; a measured *fast* wire flips it back on
    (plan fixed), a measured *slow* wire keeps it off and the calibrated
    verdict proves the unsharded plan right — either way the apparent
    auto-vs-fixed regression disappears."""
    model, params, batch = alexnet
    mesh = "data:8"
    p_base = costmodel.get_plan(model.apply, params, batch)
    p_analytic = costmodel.get_plan(model.apply, params, batch, mesh=mesh)
    fast = calibrate.injected(mesh=mesh, collective_bytes_per_second=1e15)
    slow = calibrate.injected(mesh=mesh, collective_bytes_per_second=1e7)
    p_fast = costmodel.get_plan(model.apply, params, batch, mesh=mesh,
                                calibration=fast)
    p_slow = costmodel.get_plan(model.apply, params, batch, mesh=mesh,
                                calibration=slow)
    assert p_analytic.sum_methods()["conv0"] == "contrib"
    assert p_fast.sum_methods()["conv0"] == "stash"      # the flip
    assert p_slow.sum_methods()["conv0"] == "contrib"
    assert costmodel.planner_verdict(p_fast, p_base, fast) == "sharded"
    assert costmodel.planner_verdict(p_slow, p_base, slow) == "unsharded"
    # three different costings, three distinct fingerprints — they
    # coexist in the cache/store instead of shadowing each other
    assert len({p_analytic.fingerprint, p_fast.fingerprint,
                p_slow.fingerprint}) == 3
    assert p_fast.calibration == fast.digest()
    assert p_slow.calibration == slow.digest()
    assert p_analytic.calibration == ""


# ---------------------------------------------------------------------------
# The mispredict loop: exactly one re-plan, bitwise-equal training.


def test_mispredict_triggers_exactly_one_replan_bitwise_equal(toy_model):
    params0, batch_fn = toy_model[1], _batch_fn(toy_model[2])
    calib = calibrate.injected()
    mon = StepMonitor()

    ref = _engine(toy_model, calibration=calib)
    ref_p, ref_o = _drive(ref, params0, batch_fn)

    eng = _engine(toy_model, calibration=calib, monitor=mon)
    bad = eng.predicted_step_seconds() * 10        # constant 10x miss
    got_p, got_o = _drive(eng, params0, batch_fn, feed_seconds=bad)

    # exactly one re-plan: the retimed calibration *closes* the gap, so
    # the same divergence does not re-fire every step
    assert len(eng.replan_events) == 1
    ev = eng.replan_events[0]
    assert ev.ratio == pytest.approx(10.0, rel=1e-6)
    assert ev.old_calibration == calib.digest()
    assert ev.new_calibration != calib.digest()
    # the constants changed, so the fingerprint changed (fail-safe key)…
    assert ev.new_fingerprint != ev.old_fingerprint
    # …but the realization did not: re-planning here is pure re-pricing
    assert ev.plan_changed is False
    # after the re-plan the prediction matches what was measured
    assert eng.predicted_step_seconds() == pytest.approx(bad, rel=1e-6)
    # the retimed calibration is registered for the next process/engine
    assert calibrate.lookup(()) is not None
    assert calibrate.lookup(()).source == "replan"

    # the differential core: params, optimizer state, and ledger are
    # bit-identical to the run that never re-planned
    assert _bitwise_equal(ref_p, got_p)
    assert _bitwise_equal(ref_o, got_o)
    assert eng.accountant.state_dict() == ref.accountant.state_dict()
    assert eng.accountant.steps == STEPS

    # the monitor saw it and reset its EMA baseline
    assert mon.replans == [(ev.step, pytest.approx(ev.ratio))]
    state = mon.state_dict()
    assert StepMonitor.from_state(state).replans == mon.replans


def test_accurate_prediction_never_replans(toy_model):
    params0, batch_fn = toy_model[1], _batch_fn(toy_model[2])
    eng = _engine(toy_model, calibration=calibrate.injected())
    _drive(eng, params0, batch_fn,
           feed_seconds=eng.predicted_step_seconds() * 1.2)   # within ±50%
    assert eng.replan_events == []


def test_observe_is_inert_without_calibration(toy_model):
    params0, batch_fn = toy_model[1], _batch_fn(toy_model[2])
    eng = _engine(toy_model)                       # analytic constants
    assert eng.calibration is None
    _drive(eng, params0, batch_fn, feed_seconds=1e3)
    assert eng.replan_events == []
    eng2 = _engine(toy_model, calibration=calibrate.injected(),
                   threshold=None)                 # loop disabled
    _drive(eng2, params0, batch_fn, feed_seconds=1e3)
    assert eng2.replan_events == []


def test_single_observation_cannot_replan(toy_model):
    """One compile-tainted step must not fire the loop."""
    eng = _engine(toy_model, calibration=calibrate.injected())
    assert eng.observe_step_time(eng.predicted_step_seconds() * 100,
                                 step=0) is None
    assert eng.replan_events == []


def test_explain_surfaces_calibration_and_replans(toy_model):
    # the analytic engine names its constants (nothing registered yet)
    assert "analytic fallback" in _engine(toy_model).explain()
    calib = calibrate.injected()
    eng = _engine(toy_model, calibration=calib)
    text = eng.explain()
    assert f"calibration: {calib.digest()}" in text
    assert "source=injected" in text
    assert "mispredict threshold" in text
    bad = eng.predicted_step_seconds() * 10
    eng.observe_step_time(bad, step=0)
    eng.observe_step_time(bad, step=1)
    assert "re-plan @ step 1" in eng.explain()


# ---------------------------------------------------------------------------
# Fail-safe: absent/corrupt blobs degrade with a named warning.


def test_absent_calibration_warns_and_falls_back(tmp_path):
    with pytest.warns(calibrate.CalibrationFallbackWarning,
                      match="FileNotFoundError"):
        assert calibrate.load_or_fallback(
            str(tmp_path / "nope.json")) is None


def test_corrupt_calibration_warns_and_engine_plans_analytic(
        toy_model, tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text('{"format": 1, "hardware"')    # truncated mid-key
    with pytest.warns(calibrate.CalibrationFallbackWarning,
                      match="CalibrationFormatError"):
        eng = _engine(toy_model, calibration=str(bad))
    assert eng.calibration is None
    assert eng.plan().calibration == ""           # analytic constants
    # and the engine still trains
    params0, batch_fn = toy_model[1], _batch_fn(toy_model[2])
    _drive(eng, params0, batch_fn, steps=1)


def test_check_plan_matches_names_calibration_field(toy_model):
    apply_fn, params, batch = toy_model
    plan = costmodel.get_plan(apply_fn, params, batch)   # analytic
    calib = calibrate.injected()
    with pytest.raises(ValueError, match="calibration mismatch"):
        costmodel.check_plan_matches(plan, calibration=calib)
    costmodel.check_plan_matches(plan, calibration="")   # clean
    cal_plan = costmodel.get_plan(apply_fn, params, batch,
                                  calibration=calib)
    costmodel.check_plan_matches(cal_plan, calibration=calib)
    with pytest.raises(ValueError, match="calibration mismatch"):
        costmodel.check_plan_matches(cal_plan, calibration="")


def test_injecting_plan_from_other_constants_fails_at_init(toy_model):
    """An ExecPlan priced under measured constants injected into an
    analytic engine is stale the moment it is handed over — named at
    construction, not at step time."""
    apply_fn, params, batch = toy_model
    calib = calibrate.injected()
    plan = costmodel.get_plan(apply_fn, params, batch, calibration=calib)
    with pytest.raises(ValueError, match="calibration mismatch"):
        PrivacyEngine(apply_fn, params, batch,
                      dp=DPConfig(l2_clip=0.1), plan=plan)


# ---------------------------------------------------------------------------
# Mutation harness: every tampered blob is rejected by name.


def _valid_payload(**kw):
    return calibrate.injected(**kw).to_payload()


def test_mutation_wrong_hardware_signature(tmp_path):
    calib = calibrate.injected(hardware="tpu:TPU v9:4096")
    path = str(tmp_path / "c.json")
    calibrate.save_calibration(path, calib)
    with pytest.raises(calibrate.CalibrationHardwareMismatch,
                       match="tpu:TPU v9:4096"):
        calibrate.load_calibration(path)
    # …and only the hardware check was waived, nothing else
    assert calibrate.load_calibration(path, expect_hardware=False) == calib


def test_mutation_wrong_mesh(tmp_path):
    calib = calibrate.injected(mesh="data:4",
                               collective_bytes_per_second=1e9)
    path = str(tmp_path / "c.json")
    calibrate.save_calibration(path, calib)
    with pytest.raises(calibrate.CalibrationMeshMismatch, match="data=8"):
        calibrate.load_calibration(path, expect_mesh="data:8")


def test_mutation_truncated_payload(tmp_path):
    calib = calibrate.injected()
    blob = calib.to_json()
    path = tmp_path / "c.json"
    path.write_text(blob[: len(blob) // 2])
    with pytest.raises(calibrate.CalibrationFormatError,
                       match="not valid JSON"):
        calibrate.load_calibration(str(path))


def test_mutation_nan_bandwidth(tmp_path):
    p = _valid_payload(mesh="data:2", collective_bytes_per_second=1e9)
    p["collective_bytes_per_second"]["data"] = float("nan")
    path = tmp_path / "c.json"
    path.write_text(json.dumps(p))
    with pytest.raises(calibrate.CalibrationValueError,
                       match="finite positive"):
        calibrate.load_calibration(str(path))


@pytest.mark.parametrize("value", [0.0, -1.0, float("inf")])
def test_mutation_nonpositive_flop_rate(tmp_path, value):
    p = _valid_payload()
    p["flops_per_second"] = value
    path = tmp_path / "c.json"
    path.write_text(json.dumps(p))
    with pytest.raises(calibrate.CalibrationValueError,
                       match="flops_per_second"):
        calibrate.load_calibration(str(path))


def test_mutation_missing_field(tmp_path):
    p = _valid_payload()
    del p["hbm_bytes_per_second"]
    path = tmp_path / "c.json"
    path.write_text(json.dumps(p))
    with pytest.raises(calibrate.CalibrationFormatError,
                       match="hbm_bytes_per_second"):
        calibrate.load_calibration(str(path))


def test_mutation_foreign_format_version(tmp_path):
    p = _valid_payload()
    p["format"] = 99
    path = tmp_path / "c.json"
    path.write_text(json.dumps(p))
    with pytest.raises(calibrate.CalibrationFormatError,
                       match="format 99"):
        calibrate.load_calibration(str(path))


def test_mutation_tampered_plan_store_calibration(toy_model, tmp_path):
    """A plan store whose embedded calibration was tampered (NaN rate)
    must refuse whole — plans priced under garbage constants must not
    load, let alone execute."""
    eng = _engine(toy_model, calibration=calibrate.injected())
    path = str(tmp_path / "plans.json")
    eng.save_plan(path)
    doc = json.load(open(path))
    assert doc["calibrations"], "store must persist its calibration"
    doc["calibrations"][0]["flops_per_second"] = float("nan")
    json.dump(doc, open(path, "w"))
    costmodel.clear_plan_store()
    calibrate.clear_registry()
    with pytest.raises(calibrate.CalibrationValueError):
        costmodel.load_plan_store(path)
    assert costmodel.plan_cache_info()["store"] == 0   # nothing half-loaded


def test_mutation_every_error_is_a_named_calibration_error():
    """The soft consumers catch CalibrationError; every named rejection
    must be a subclass or the fallback silently turns into a crash."""
    for cls in (calibrate.CalibrationFormatError,
                calibrate.CalibrationValueError,
                calibrate.CalibrationHardwareMismatch,
                calibrate.CalibrationMeshMismatch):
        assert issubclass(cls, calibrate.CalibrationError)
    assert issubclass(calibrate.CalibrationFallbackWarning, UserWarning)
    # the warning must never be caught (and swallowed) as a rejection
    assert not issubclass(calibrate.CalibrationFallbackWarning,
                          calibrate.CalibrationError)


# ---------------------------------------------------------------------------
# Kernel sweep plumbing: the measured VMEM budget reaches the autotuner.


def test_vmem_budget_precedence(monkeypatch):
    assert kops.vmem_budget() == kops.VMEM_BUDGET      # analytic default
    calib = calibrate.injected(
        kernels={"pe_conv_grad": {"vmem_budget": 4 << 20, "bd": 8}})
    calibrate.register(calib)
    assert kops.vmem_budget() == 4 << 20               # measured winner
    monkeypatch.setenv("REPRO_VMEM_BUDGET", str(1 << 20))
    assert kops.vmem_budget() == 1 << 20               # env overrides both


def test_quick_harness_measures_live_hardware():
    """The harness end-to-end on this host: finite positive rates, the
    live hardware signature, and a pe_conv_grad sweep winner that is a
    real budget from the sweep grid."""
    calib = calibrate.measure(quick=True)
    assert calib.hardware == calibrate.hardware_signature()
    assert math.isfinite(calib.flops_per_second)
    assert calib.flops_per_second > 0
    assert calib.hbm_bytes_per_second > 0
    pe = calib.kernels["pe_conv_grad"]
    assert str(pe["vmem_budget"]) in pe["sweep"]       # winner from grid
    assert pe["bd"] >= 1
    # round-trips through its own serialization
    assert calibrate.Calibration.from_json(calib.to_json()) == calib


# ---------------------------------------------------------------------------
# Sharded lane (the 8-device CI job).


@needs_8_devices
@pytest.mark.multidevice
def test_sharded_replan_continues_training(toy_model):
    """The mispredict loop under a real data:8 mesh: a re-plan retimes
    the *wire* (the mesh plan moves collective bytes), rebuilds the
    sharded jitted step, and training continues on the same noise stream
    with the ledger intact."""
    batch = jax.tree.map(lambda a: jnp.concatenate([a, a], axis=0),
                         toy_model[2])
    params0, batch_fn = toy_model[1], _batch_fn(batch)
    mesh = jax.make_mesh((8,), ("data",))
    calib = calibrate.injected(mesh="data:8",
                               collective_bytes_per_second=1e9)
    mon = StepMonitor()
    eng = _engine(toy_model, calibration=calib, mesh=mesh, batch=batch,
                  monitor=mon)
    bad = eng.predicted_step_seconds() * 10
    got_p, _ = _drive(eng, params0, batch_fn, feed_seconds=bad)
    assert len(eng.replan_events) == 1
    ev = eng.replan_events[0]
    # the divergence was attributed to the wire, not the FLOP rate
    new = eng.calibration
    assert new.source == "replan"
    assert new.flops_per_second == calib.flops_per_second
    assert new.collective_bytes_per_second["data"] \
        < calib.collective_bytes_per_second["data"]
    assert mon.replans == [(ev.step, pytest.approx(ev.ratio))]
    assert eng.accountant.steps == STEPS
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(got_p))


# ---------------------------------------------------------------------------
# Per-axis retiming (2D meshes)


def test_retimed_prices_old_wire_share_per_axis():
    """With a per-axis byte breakdown, retiming computes the old wire
    share on the axes the traffic actually crossed and rescales every
    measured bandwidth so the new prediction closes the gap exactly."""
    calib = calibrate.injected(
        mesh="data:4,model:2", flops_per_second=1e12,
        collective_bytes_per_second={"data": 16e9, "model": 2e9})
    by_axis = (("data", 64 * 2**20), ("model", 8 * 2**20))
    total = sum(b for _, b in by_axis)
    wire_old = sum(b / {"data": 16e9, "model": 2e9}[a] for a, b in by_axis)
    predicted = wire_old + 2e-3          # 2 ms of compute
    measured = 2.0 * wire_old + 2e-3     # wire twice as slow as measured
    new = calib.retimed(predicted_s=predicted, measured_s=measured,
                        coll_bytes=total, coll_bytes_by_axis=by_axis)
    # both axes rescaled by the same factor (the observed wire slowdown)
    assert new.collective_bytes_per_second["data"] == pytest.approx(8e9)
    assert new.collective_bytes_per_second["model"] == pytest.approx(1e9)
    # the compute rate is untouched — the wire absorbed the whole gap
    assert new.flops_per_second == calib.flops_per_second
    assert new.source == "replan"


def test_retimed_per_axis_emits_no_axisless_fallback_warning():
    import warnings as _w
    calib = calibrate.injected(
        mesh="data:4,model:2", flops_per_second=1e12,
        collective_bytes_per_second={"data": 16e9, "model": 2e9})
    by_axis = (("data", 2**20), ("model", 2**18))
    with _w.catch_warnings():
        _w.simplefilter("error",
                        calibrate.CalibrationAxisFallbackWarning)
        calib.retimed(predicted_s=1e-3, measured_s=2e-3,
                      coll_bytes=2**20 + 2**18, coll_bytes_by_axis=by_axis)


def test_retimed_without_wire_share_falls_back_to_flop_rate():
    """Zero collective traffic: nothing to attribute to the wire — the
    FLOP rate absorbs the divergence (also the legacy axis-less path)."""
    calib = calibrate.injected(
        mesh="data:4,model:2", flops_per_second=1e12,
        collective_bytes_per_second={"data": 16e9, "model": 2e9})
    new = calib.retimed(predicted_s=1e-3, measured_s=2e-3, coll_bytes=0.0)
    assert new.flops_per_second == pytest.approx(5e11)
    assert new.collective_bytes_per_second \
        == calib.collective_bytes_per_second
