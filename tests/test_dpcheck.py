"""Static DP verification (repro.analysis) — the verifier's own tests.

Three groups:

* **Clean lanes** — ``engine.verify()`` returns a clean report for the
  real reduced alexnet across every clip mode, single-device and (on a
  forced 8-device host) sharded.  These are the false-positive guard:
  the verifier must accept the code we actually ship.
* **Mutation harness** — the false-negative guard.  Each test installs
  a classic DP-SGD bug (drop the clip, reuse a noise key, add noise
  twice, reduce-before-clip, bf16 norms) by patching the real
  implementation, re-traces, and asserts the verifier flags it with the
  specific finding code.  A verifier that misses any of these is worse
  than no verifier: it certifies broken privacy.
* **Key provenance** — ``_check_key`` must reject an explicit ``key=``
  whose provenance contradicts ``step=`` (raising
  :class:`KeyProvenanceError`), since replaying a step with foreign
  noise breaks the deterministic-replay accounting argument.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core.clipping as clipping
import repro.core.engine as engine_mod
import repro.core.kinds as kinds
import repro.core.strategies as strategies
from repro.configs import get_config
from repro.core import (ClipPolicy, DPConfig, KeyProvenanceError,
                        PrivacyEngine, costmodel)
from repro.core.tapper import Tapper
from repro.launch.train import make_batch_fn
from repro.models.registry import build_model

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

CLIP_MODES = ["flat", "per_layer", "stale"]


@pytest.fixture(autouse=True)
def _fresh_plans():
    # Mutants change what the traced step looks like; a cached plan from
    # a previous (unmutated) trace would mask or fabricate mismatches.
    costmodel.clear_plan_cache()
    yield
    costmodel.clear_plan_cache()


def _engine(mode="flat", mesh=None, run_seed=0, noise=0.8):
    cfg = get_config("alexnet").reduced()
    model = build_model(cfg)
    params0, _ = model.init(jax.random.PRNGKey(0))
    dpc = DPConfig(l2_clip=1.0, noise_multiplier=noise, strategy="auto",
                   clipping=ClipPolicy(mode=mode))
    return PrivacyEngine(model.apply, params0,
                         make_batch_fn(cfg, 8, 64)(0), dp=dpc,
                         optimizer="adamw", lr=1e-3, mesh=mesh,
                         run_seed=run_seed)


def _codes(report):
    return sorted({f.code for f in report.errors})


# ---------------------------------------------------------------------------
# Clean lanes: no false positives on the shipped implementation.


@pytest.mark.parametrize("mode", CLIP_MODES)
def test_clean_lane_single_device(mode):
    report = _engine(mode).verify()
    assert report.ok, report.summary()
    assert not report.warnings, report.summary()
    # Every pass actually ran (a pass that silently skipped proves
    # nothing).
    for section in ("taint", "noise", "sharding", "plan"):
        assert section in report.checked


@needs_8_devices
@pytest.mark.parametrize("mode", CLIP_MODES)
def test_clean_lane_data8(mode):
    from repro.launch.mesh import make_mesh_from_spec
    report = _engine(mode, mesh=make_mesh_from_spec("data:8")).verify()
    assert report.ok, report.summary()
    assert not report.warnings, report.summary()


def test_verify_report_surface():
    report = _engine().verify()
    assert "PASS" in report.summary()
    # Info-level notes (conservative-fallback disclosures) are fine;
    # anything stronger is not.
    assert report.errors == [] and report.warnings == []
    # raise_on_error is a no-op on a clean report...
    _engine().verify(raise_on_error=True)


# ---------------------------------------------------------------------------
# Mutation harness: classic DP bugs must be flagged.


def _verify_mutated(monkeypatch, patches, mode="flat"):
    for obj, attr, val in patches:
        monkeypatch.setattr(obj, attr, val)
    costmodel.clear_plan_cache()
    return _engine(mode).verify()


def test_mutant_dropped_clip(monkeypatch):
    def no_clip(norms_sq, l2_clip, eps=1e-12, *, mode="flat"):
        return jnp.ones_like(norms_sq)

    report = _verify_mutated(
        monkeypatch, [(strategies, "clip_coefficients", no_clip)])
    codes = _codes(report)
    assert "clip_missing" in codes, codes
    assert "unclipped_batch_reduction" in codes, codes


def test_mutant_key_reuse(monkeypatch):
    def reuse_key(grad_sum, key, noise_multiplier, l2_clip):
        if noise_multiplier == 0.0:
            return grad_sum
        leaves, treedef = jax.tree.flatten(grad_sum)
        sigma = noise_multiplier * l2_clip
        noisy = [(g.astype(jnp.float32)
                  + sigma * jax.random.normal(key, g.shape, jnp.float32)
                  ).astype(g.dtype) for g in leaves]
        return jax.tree.unflatten(treedef, noisy)

    report = _verify_mutated(
        monkeypatch, [(clipping, "add_noise", reuse_key)])
    assert "key_reuse" in _codes(report), _codes(report)


def test_mutant_double_noise(monkeypatch):
    orig = clipping.add_noise

    def double_noise(grad_sum, key, noise_multiplier, l2_clip):
        g1 = orig(grad_sum, key, noise_multiplier, l2_clip)
        return orig(g1, jax.random.fold_in(key, 1), noise_multiplier,
                    l2_clip)

    report = _verify_mutated(
        monkeypatch, [(clipping, "add_noise", double_noise)])
    assert "noise_duplicated" in _codes(report), _codes(report)


def test_mutant_reduce_before_clip(monkeypatch):
    # The textbook bug: clip the *mean* gradient by its global norm
    # instead of clipping each example's gradient before summing.
    # Sensitivity is unbounded; the verifier must see the batch-axis
    # reduction happen with no per-example clip on its history.
    def mean_then_scale(apply_fn, params, batch, *, cfg, key=None,
                        denom=None, plan=None, clip_state=None):
        def mean_loss(p):
            return jnp.mean(apply_fn(p, batch, Tapper()))
        loss, grad = jax.value_and_grad(mean_loss)(params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(grad)))
        scale = jnp.minimum(1.0, cfg.l2_clip / (gnorm + 1e-12))
        grad = jax.tree.map(lambda g: g * scale, grad)
        grad = clipping.add_noise(grad, key, cfg.noise_multiplier,
                                  cfg.l2_clip)
        return loss, grad, {"clip_fraction": jnp.zeros(())}

    report = _verify_mutated(
        monkeypatch, [(engine_mod, "dp_gradient", mean_then_scale)])
    codes = _codes(report)
    assert "unclipped_batch_reduction" in codes, codes
    assert "clip_missing" in codes, codes


def test_mutant_bf16_norms(monkeypatch):
    orig = kinds.dense_norm_sq

    def bf16_norms(meta, cap, dy, method="auto"):
        return orig(meta, cap, dy, method=method).astype(jnp.bfloat16)

    report = _verify_mutated(
        monkeypatch, [(kinds, "dense_norm_sq", bf16_norms)])
    assert "norm_low_precision" in _codes(report), _codes(report)


def test_mutant_raises_with_raise_on_error(monkeypatch):
    from repro.analysis import DPVerificationError

    def no_clip(norms_sq, l2_clip, eps=1e-12, *, mode="flat"):
        return jnp.ones_like(norms_sq)

    monkeypatch.setattr(strategies, "clip_coefficients", no_clip)
    costmodel.clear_plan_cache()
    with pytest.raises(DPVerificationError, match="clip"):
        _engine().verify(raise_on_error=True)


# ---------------------------------------------------------------------------
# Key provenance: explicit key= must match the stream's key for step=.


def test_check_key_accepts_stream_key():
    eng = _engine()
    k = eng.noise_key(7)
    out = eng._check_key(k, step=7)
    assert np.array_equal(np.asarray(out), np.asarray(k))


def test_check_key_rejects_wrong_step():
    eng = _engine()
    with pytest.raises(KeyProvenanceError, match="does not match"):
        eng._check_key(eng.noise_key(7), step=8)


def test_check_key_rejects_foreign_key():
    eng = _engine()
    with pytest.raises(KeyProvenanceError, match="does not match"):
        eng._check_key(jax.random.PRNGKey(12345), step=0)


def test_check_key_accepts_typed_stream_key():
    eng = _engine()
    typed = jax.random.wrap_key_data(jnp.asarray(eng.noise_key(3)))
    out = eng._check_key(typed, step=3)
    assert out is typed


def test_check_key_requires_stream_for_step_claims():
    eng = _engine(run_seed=None)
    with pytest.raises(KeyProvenanceError, match="no\\s+noise stream"):
        eng._check_key(jax.random.PRNGKey(0), step=4)


def test_check_key_rejects_tracer_key():
    eng = _engine()

    @jax.jit
    def traced(k):
        return eng._check_key(k, step=2)

    with pytest.raises(KeyProvenanceError, match="tracer"):
        traced(eng.noise_key(2))


def test_key_provenance_error_is_value_error():
    # Pre-existing callers catch ValueError from _check_key; the named
    # subclass must not break them.
    assert issubclass(KeyProvenanceError, ValueError)
