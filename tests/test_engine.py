"""PrivacyEngine equivalence suite: engine vs legacy dp_gradient on a CNN
and a tied-embedding LM, plan JSON round-trip, probe-free execution from a
deserialized plan, plan-driven auto-microbatching, and the restructured
DPConfig (NormCfg nesting, per-layer overrides, legacy-kwarg shims)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tree_maxdiff
from repro.core import (DPConfig, ExecPlan, NormCfg, PrivacyEngine,
                        clipped_grad_sum, costmodel)
from repro.core.clipping import dp_gradient
from repro.core.tapper import STATS
from repro.configs import get_config
from repro.models.registry import build_model

TOL = 1e-5


def _bitwise_equal(a, b):
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# Engine == legacy dp_gradient


def test_engine_matches_dp_gradient_toy(toy_model):
    apply_fn, params, batch = toy_model
    dp = DPConfig(l2_clip=0.1)
    loss_l, grad_l, aux_l = dp_gradient(apply_fn, params, batch, cfg=dp)
    engine = PrivacyEngine(apply_fn, params, batch, dp=dp)
    loss_e, grad_e, aux_e = engine.noisy_grad(params, batch)
    assert float(loss_l) == float(loss_e)
    assert _bitwise_equal(grad_l, grad_e)
    np.testing.assert_array_equal(np.asarray(aux_l["per_example_norms"]),
                                  np.asarray(aux_e["per_example_norms"]))


def test_engine_matches_dp_gradient_cnn():
    cfg = get_config("alexnet").replace(img_size=64, n_classes=10)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = {"img": jnp.array(rng.randn(2, 3, 64, 64), jnp.float32),
             "label": jnp.array(rng.randint(0, 10, (2,)))}
    dp = DPConfig(l2_clip=1.0)
    _, grad_l, _ = dp_gradient(model.apply, params, batch, cfg=dp)
    engine = PrivacyEngine(model.apply, params, batch, dp=dp)
    _, grad_e, _ = engine.noisy_grad(params, batch)
    assert _bitwise_equal(grad_l, grad_e)


def test_engine_matches_dp_gradient_lm_tied():
    cfg = get_config("llama3.2-1b").reduced()
    assert cfg.tie_embeddings
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    batch = {"tokens": jnp.array(rng.randint(0, cfg.vocab, (2, 8))),
             "labels": jnp.array(rng.randint(0, cfg.vocab, (2, 8)))}
    dp = DPConfig(l2_clip=1.0)
    _, grad_l, _ = dp_gradient(model.apply, params, batch, cfg=dp)
    engine = PrivacyEngine(model.apply, params, batch, dp=dp)
    _, grad_e, _ = engine.noisy_grad(params, batch)
    assert _bitwise_equal(grad_l, grad_e)
    # and both match the naive oracle
    _, gsum, _ = clipped_grad_sum(model.apply, params, batch, l2_clip=1.0,
                                  strategy="naive")
    B = batch["tokens"].shape[0]
    ref = jax.tree.map(lambda g: g / B, gsum)
    scale = max(float(jnp.abs(x).max()) for x in jax.tree.leaves(ref))
    assert tree_maxdiff(grad_e, ref) < 1e-4 * max(scale, 1.0)


def test_engine_steady_state_one_forward_one_backward(toy_model):
    apply_fn, params, batch = toy_model
    engine = PrivacyEngine(apply_fn, params, batch, dp=DPConfig(l2_clip=0.1))
    engine.noisy_grad(params, batch)      # warm the plan cache
    STATS.reset()
    engine.noisy_grad(params, batch)
    assert STATS.snapshot() == {"forwards": 1, "backwards": 1, "probes": 0}


# ---------------------------------------------------------------------------
# Plan serialization


def test_plan_json_roundtrip(toy_model):
    apply_fn, params, batch = toy_model
    plan = PrivacyEngine(apply_fn, params, batch).plan()
    restored = ExecPlan.from_json(plan.to_json())
    assert restored == plan
    assert restored.fingerprint == plan.fingerprint
    assert restored.tap_shapes == plan.tap_shapes
    # tampering breaks equality
    bad = dataclasses.replace(restored, needs_backward=True)
    assert bad != plan


def test_deserialized_plan_executes_probe_free(toy_model):
    apply_fn, params, batch = toy_model
    dp = DPConfig(l2_clip=0.1)
    engine = PrivacyEngine(apply_fn, params, batch, dp=dp)
    _, grad_ref, _ = engine.noisy_grad(params, batch)
    restored = ExecPlan.from_json(engine.plan().to_json())
    costmodel.clear_plan_cache()
    engine2 = PrivacyEngine(apply_fn, params, batch, dp=dp, plan=restored)
    STATS.reset()
    _, grad, _ = engine2.noisy_grad(params, batch)
    assert STATS.snapshot() == {"forwards": 1, "backwards": 1, "probes": 0}
    assert _bitwise_equal(grad, grad_ref)


def test_plan_store_hit_skips_probe(toy_model, tmp_path):
    apply_fn, params, batch = toy_model
    engine = PrivacyEngine(apply_fn, params, batch)
    path = str(tmp_path / "plans.json")
    engine.save_plan(path)
    costmodel.clear_plan_cache()
    costmodel.clear_plan_store()
    try:
        assert costmodel.load_plan_store(path) == 1
        STATS.reset()
        engine2 = PrivacyEngine(apply_fn, params, batch)
        engine2.plan()
        assert STATS.probes == 0
    finally:
        costmodel.clear_plan_store()


def test_stale_plan_fails_loudly(toy_model):
    apply_fn, params, batch = toy_model
    plan = PrivacyEngine(apply_fn, params, batch).plan()
    renamed = {("other_" + n): lp for n, lp in plan.layers.items()}
    stale = dataclasses.replace(plan, layers=renamed)
    engine = PrivacyEngine(apply_fn, params, batch, plan=stale)
    with pytest.raises(ValueError, match="does not match"):
        engine.noisy_grad(params, batch)


# ---------------------------------------------------------------------------
# Plan-driven auto-microbatching


def test_auto_microbatches_matches_explicit(toy_model):
    apply_fn, params, batch = toy_model
    norm = NormCfg(mem_budget=1 << 14)   # tiny: forces a split
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(l2_clip=0.1, microbatches="auto",
                                       norm=norm))
    m = engine.microbatches()
    assert m > 1
    B = batch["label"].shape[0]
    assert B % m == 0
    _, grad_auto, _ = engine.noisy_grad(params, batch)
    explicit = PrivacyEngine(apply_fn, params, batch,
                             dp=DPConfig(l2_clip=0.1, microbatches=m,
                                         norm=norm))
    _, grad_exp, _ = explicit.noisy_grad(params, batch)
    assert _bitwise_equal(grad_auto, grad_exp)
    # and the split changes nothing vs the unsplit gradient
    _, grad_one, _ = PrivacyEngine(apply_fn, params, batch,
                                   dp=DPConfig(l2_clip=0.1)).noisy_grad(
        params, batch)
    assert tree_maxdiff(grad_auto, grad_one) < TOL


def test_auto_microbatches_defaults_to_one(toy_model):
    apply_fn, params, batch = toy_model
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(microbatches="auto"))
    assert engine.microbatches() == 1   # toy model fits the default budget


def test_auto_microbatches_divisor_selection():
    plan = type("P", (), {})()          # duck-typed plan stub
    plan.capture_bytes = 900.0
    plan.peak_stash_bytes = lambda: 100.0
    assert costmodel.auto_microbatches(plan, 8, mem_budget=1000) == 1
    assert costmodel.auto_microbatches(plan, 8, mem_budget=500) == 2
    assert costmodel.auto_microbatches(plan, 8, mem_budget=300) == 4
    assert costmodel.auto_microbatches(plan, 6, mem_budget=400) == 3
    assert costmodel.auto_microbatches(plan, 8, mem_budget=1) == 8


# ---------------------------------------------------------------------------
# Private steps


def test_private_step_updates_and_accounts(toy_model):
    from repro.optim import adamw_init
    apply_fn, params, batch = toy_model
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(l2_clip=0.1, noise_multiplier=0.7),
                           sampling_rate=4 / 1024, lr=1e-2)
    opt = adamw_init(params)
    p, opt, loss, aux = engine.private_step(params, opt, batch,
                                            jax.random.PRNGKey(0))
    assert np.isfinite(float(loss))
    assert tree_maxdiff(p, params) > 0.0
    assert engine.accountant.steps == 1
    assert np.isfinite(engine.epsilon())
    p, opt, loss, aux = engine.private_step(p, opt, batch,
                                            jax.random.PRNGKey(1))
    assert engine.accountant.steps == 2


def test_private_step_requires_key_when_noisy(toy_model):
    from repro.optim import adamw_init
    apply_fn, params, batch = toy_model
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(noise_multiplier=1.0))
    with pytest.raises(ValueError, match="requires a PRNG key"):
        engine.private_step(params, adamw_init(params), batch)


# ---------------------------------------------------------------------------
# DPConfig restructure


def test_dpconfig_legacy_kwargs_map_to_normcfg():
    with pytest.warns(DeprecationWarning):
        cfg = DPConfig(norm_method="gram", embed_norm="segsum",
                       conv_impl="bgc", conv_norm=None)
    assert cfg.norm == NormCfg(dense="gram", embed="segsum", conv="auto",
                               conv_impl="bgc")
    # read-only legacy views
    assert cfg.norm_method == "gram"
    assert cfg.embed_norm == "segsum"
    assert cfg.conv_impl == "bgc"
    assert cfg.conv_norm == "auto"   # the None sentinel is gone


def test_dpconfig_validates_microbatches():
    with pytest.raises(ValueError, match="microbatches"):
        DPConfig(microbatches=0)
    with pytest.raises(ValueError, match="microbatches"):
        DPConfig(microbatches="many")
    assert DPConfig(microbatches="auto").microbatches == "auto"


def test_dpconfig_is_hashable_and_frozen():
    cfg = DPConfig(overrides={"conv1": "ghost"})
    hash(cfg)
    assert cfg.overrides == (("conv1", "ghost"),)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.l2_clip = 2.0


def test_per_layer_overrides_respected(toy_model):
    apply_fn, params, batch = toy_model
    dp = DPConfig(l2_clip=0.05, overrides={"conv1": "ghost"})
    engine = PrivacyEngine(apply_fn, params, batch, dp=dp)
    plan = engine.plan()
    assert plan.layers["conv1"].norm_method == "ghost"   # auto picks pe
    _, gsum, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=0.05,
                                  strategy="naive")
    B = batch["label"].shape[0]
    ref = jax.tree.map(lambda g: g / B, gsum)
    _, grad, _ = engine.noisy_grad(params, batch)
    assert tree_maxdiff(grad, ref) < 1e-4


def test_override_glob_skips_non_overridable_kinds(toy_model):
    """A block-level glob sweeps up scale/local_vjp taps; those must be
    ignored (they have no norm vocabulary), not rejected — and the
    override still lands on the block's dense layers."""
    apply_fn, params, batch = toy_model
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(l2_clip=0.05,
                                       overrides={"blocks/*": "gram"}))
    plan = engine.plan()   # blocks/nrm is a scale tap — must not raise
    assert plan.layers["blocks/fc"].norm_method == "gram"
    assert plan.layers["blocks/nrm"].norm_method == "pe"   # untouched
    _, gsum, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=0.05,
                                  strategy="naive")
    B = batch["label"].shape[0]
    ref = jax.tree.map(lambda g: g / B, gsum)
    _, grad, _ = engine.noisy_grad(params, batch)
    assert tree_maxdiff(grad, ref) < 1e-4


def test_override_first_match_wins_in_given_order():
    """Dict insertion order is the priority order: a specific pattern
    listed before a broad glob must win even when sorting would reorder
    them."""
    ov = costmodel.normalize_overrides(
        {"blocks/attn": "gram", "blocks/*": "stream"})
    assert ov == (("blocks/attn", "gram"), ("blocks/*", "stream"))
    assert costmodel._override_for("blocks/attn", "dense", ov) == "gram"
    assert costmodel._override_for("blocks/mlp", "dense", ov) == "stream"


def test_override_invalid_method_raises(toy_model):
    apply_fn, params, batch = toy_model
    engine = PrivacyEngine(apply_fn, params, batch,
                           dp=DPConfig(overrides={"conv1": "stream"}))
    with pytest.raises(ValueError, match="invalid for conv"):
        engine.plan()


def test_engine_explain_mentions_every_layer(toy_model):
    apply_fn, params, batch = toy_model
    engine = PrivacyEngine(apply_fn, params, batch)
    text = engine.explain()
    for name in engine.plan().layers:
        assert name in text
    assert "1 fwd + 1 bwd" in text
