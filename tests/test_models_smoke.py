"""Per-architecture smoke tests (reduced configs, CPU): one DP-ghost train
gradient + prefill + decode step; asserts shapes and finiteness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, PAPER_IDS, get_config
from repro.core import DPConfig
from repro.core.clipping import dp_gradient
from repro.models.registry import build_model

B, T = 2, 16


def make_batch(cfg, rng):
    if cfg.family == "cnn":
        return {"img": jnp.array(rng.randn(B, 3, cfg.img_size, cfg.img_size),
                                 jnp.float32),
                "label": jnp.array(rng.randint(0, cfg.n_classes, (B,)))}
    if cfg.family == "encdec":
        return {"src_frames": jnp.array(rng.randn(B, 8, cfg.d_model),
                                        jnp.float32),
                "tokens": jnp.array(rng.randint(0, cfg.vocab, (B, 8))),
                "labels": jnp.array(rng.randint(0, cfg.vocab, (B, 8)))}
    return {"tokens": jnp.array(rng.randint(0, cfg.vocab, (B, T))),
            "labels": jnp.array(rng.randint(0, cfg.vocab, (B, T)))}


@pytest.mark.parametrize("arch", ARCH_IDS + PAPER_IDS)
def test_arch_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = np.random.RandomState(hash(arch) % 1000)
    params, axes = model.init(jax.random.PRNGKey(0))
    # every param leaf has a logical-axes tuple of matching rank
    for (kp, leaf), (_, ax) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(
                axes, is_leaf=lambda x: isinstance(x, tuple))):
        assert len(ax) == leaf.ndim, (jax.tree_util.keystr(kp), ax)

    batch = make_batch(cfg, rng)
    loss, grad, aux = dp_gradient(
        model.apply, params, batch,
        cfg=DPConfig(l2_clip=1.0, noise_multiplier=0.0,
                     strategy=cfg.dp_strategy),
        key=jax.random.PRNGKey(1))
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grad))
    norms = aux["per_example_norms"]
    assert norms.shape == (B,) and bool(jnp.all(norms > 0))

    if cfg.family == "cnn":
        return
    if cfg.family == "encdec":
        logits, cache = model.prefill(params, batch["src_frames"],
                                      batch["tokens"], max_len=32)
    else:
        logits, cache = model.prefill(params, batch["tokens"], max_len=32)
    assert logits.shape == (B, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = model.decode_step(params, cache, tok)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2).all())
    assert int(cache["pos"]) > 0
