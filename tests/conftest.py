import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.tapper import Tapper, scan_with_taps


@pytest.fixture(scope="session")
def toy_model():
    """Small mixed model: conv + embedding + scanned dense blocks + affine
    norms + head.  Exercises every built-in layer kind except MoE/SSM."""
    rng = np.random.RandomState(0)
    B, C, H, W = 4, 3, 12, 12
    V, T, D, L = 11, 6, 8, 3

    params = {
        "conv1": {"w": jnp.array(rng.randn(5, C, 3, 3) * 0.2, jnp.float32),
                  "b": jnp.array(rng.randn(5) * 0.1, jnp.float32)},
        "emb": {"emb": jnp.array(rng.randn(V, D) * 0.3, jnp.float32)},
        "blocks": {"fc": {"w": jnp.array(rng.randn(L, D, D) * 0.3,
                                         jnp.float32),
                          "b": jnp.array(rng.randn(L, D) * 0.1, jnp.float32)},
                   "nrm": {"g": jnp.ones((L, D)), "b": jnp.zeros((L, D))}},
        "head": {"w": jnp.array(rng.randn(125 + D, 7) * 0.2, jnp.float32)},
    }

    def apply_fn(params, batch, tp: Tapper):
        img, ids, y = batch["img"], batch["ids"], batch["label"]
        h = tp.conv("conv1", img, params["conv1"]["w"], params["conv1"]["b"],
                    stride=2, padding=1)
        h = jax.nn.relu(h)
        h = h.reshape(h.shape[0], -1)[:, :125]
        e = tp.embed("emb", params["emb"]["emb"], ids)

        def block(stp, carry, p_l, _):
            x = stp.dense("fc", carry, p_l["fc"]["w"], p_l["fc"]["b"])
            x = jax.nn.gelu(x)
            mu = jnp.mean(x, -1, keepdims=True)
            x = (x - mu) / jnp.sqrt(jnp.var(x, -1, keepdims=True) + 1e-5)
            x = stp.scale("nrm", x, p_l["nrm"]["g"], p_l["nrm"]["b"])
            return x

        e = scan_with_taps(tp, "blocks", block, e, params["blocks"])
        feat = jnp.concatenate([h, e.mean(axis=1)], axis=-1)
        logits = tp.dense("head", feat, params["head"]["w"])
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], 1)[:, 0]

    batch = {
        "img": jnp.array(rng.randn(B, C, H, W), jnp.float32),
        "ids": jnp.array(rng.randint(0, V, (B, T))),
        "label": jnp.array(rng.randint(0, 7, (B,))),
    }
    return apply_fn, params, batch


def tree_maxdiff(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def true_norms_sq(pe_grads):
    B = jax.tree.leaves(pe_grads)[0].shape[0]
    return sum(jnp.sum(l.reshape(B, -1).astype(jnp.float32) ** 2, axis=1)
               for l in jax.tree.leaves(pe_grads))
