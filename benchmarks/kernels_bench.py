"""Kernel-level benchmark: the ghost-norm Gram reduction and the
per-example conv gradient.

Wall time on CPU compares the *XLA lowerings*; the Pallas kernels target
TPU (here they run in interpret mode, which measures nothing useful), so
the kernel's value is reported analytically: HBM bytes touched by the XLA
chunked-gram path vs the fused VMEM-tiled kernel.

``--calibrate-only`` skips the comparative benchmark and runs the
measured-cost harness (repro.calibrate) instead: flop rate, HBM and
collective bandwidth, plus the pe_conv_grad VMEM_BUDGET sweep.  The
resulting calibration JSON is what ``launch/train.py --calibration``,
``launch/dryrun.py --calibration`` and ``launch/serve.py --calibration``
pre-register, and the sweep winners are merged into BENCH_strategies.json
under the ``kernels@calibration`` key so the benchmark record carries the
measured tile choices alongside the strategy timings.

    PYTHONPATH=src python -m benchmarks.kernels_bench --calibrate-only \
        --calibration-out results/calibration.json [--mesh data:8] [--quick]
"""
from __future__ import annotations

import json
import os
import sys

if __name__ == "__main__":
    # A --mesh data:N calibration on a CPU host needs N devices before
    # the jax backend initializes.
    from repro.launch.mesh import force_host_device_count_for
    force_host_device_count_for(sys.argv)

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import kinds
from repro.core.tapper import LayerMeta
from repro.models import convops


def run():
    rng = np.random.RandomState(0)
    # --- ghost norm: gram vs stream (XLA) + analytic kernel savings
    for (B, T, Di, Do) in [(8, 256, 256, 256), (4, 1024, 512, 512)]:
        x = jnp.array(rng.randn(B, T, Di), jnp.float32)
        dy = jnp.array(rng.randn(B, T, Do), jnp.float32)
        meta = LayerMeta("dense", ("w",))
        f_gram = jax.jit(lambda a, b: kinds.dense_norm_sq(
            meta, {"x": a}, b, method="gram"))
        f_stream = jax.jit(lambda a, b: kinds.dense_norm_sq(
            meta, {"x": a}, b, method="stream"))
        tg = time_fn(f_gram, x, dy)
        ts = time_fn(f_stream, x, dy)
        # XLA gram materializes (B, chunk, T) Gram tiles in HBM twice;
        # the Pallas kernel keeps them in VMEM: HBM traffic = inputs once.
        chunk = min(T, 1024)
        xla_bytes = 4 * B * (2 * chunk * T * (T // chunk)      # two grams
                             + T * (Di + Do))                  # inputs
        kern_bytes = 4 * B * T * (Di + Do)
        emit(f"kernels/gram_norm/B{B}T{T}", tg,
             f"stream_us={ts:.0f};hbm_ratio_xla_vs_pallas="
             f"{xla_bytes / kern_bytes:.1f}")

    # --- fused gram_norm + weighted contribution: one pass over (x, δy)
    # vs the two-kernel sequence.  The separate path times the XLA
    # lowering; the fused kernel runs in interpret mode on CPU (its time
    # here is plumbing, not performance) — the analytic win is the halved
    # HBM read of x/δy.
    from repro.kernels import ops as kops
    B, T, Di, Do = 4, 256, 128, 128
    x = jnp.array(rng.randn(B, T, Di), jnp.float32)
    dy = jnp.array(rng.randn(B, T, Do), jnp.float32)
    w = jnp.array(rng.rand(B), jnp.float32)
    meta = LayerMeta("dense", ("w",))
    f_sep = jax.jit(lambda a, b, c: (
        kinds.dense_norm_sq(meta, {"x": a}, b, method="gram"),
        kinds.dense_contrib(meta, {"x": a}, b, c)))
    t_sep = time_fn(f_sep, x, dy, w)
    sep_bytes = 4 * B * T * (Di + Do) * 2          # x/δy read twice
    fused_bytes = 4 * B * T * (Di + Do)            # read once
    emit(f"kernels/gram_norm_sep/B{B}T{T}", t_sep,
         f"hbm_ratio_sep_vs_fused={sep_bytes / fused_bytes:.1f}")
    f_fused = jax.jit(lambda a, b, c: kops.gram_norm_fused(a, b, c))
    t_fused = time_fn(f_fused, x, dy, w)
    emit(f"kernels/gram_norm_fused/B{B}T{T}", t_fused,
         "interpret_mode_on_cpu")

    # --- per-example conv grad: fgc vs bgc lowering + autotuned bd tile
    for (B, C, D, HW, K) in [(8, 16, 32, 32, 3), (4, 32, 64, 16, 5)]:
        x = jnp.array(rng.randn(B, C, HW, HW), jnp.float32)
        out_sp = HW - K + 1
        dy = jnp.array(rng.randn(B, D, out_sp, out_sp), jnp.float32)
        for impl in ("fgc", "bgc"):
            f = jax.jit(lambda a, b, i=impl: convops.pe_conv_grad(
                a, b, kernel_spatial=(K, K), impl=i))
            t = time_fn(f, x, dy)
            emit(f"kernels/pe_conv/{impl}/B{B}C{C}D{D}", t, "")
        bd = kops.pick_bd(D, C, (HW, HW), (out_sp, out_sp), (K, K))
        emit(f"kernels/pe_conv/pallas_bd/B{B}C{C}D{D}", 0.0,
             f"autotuned_bd={bd}_of_D{D}")


def calibrate_only(calibration_out: str = "results/calibration.json",
                   mesh_spec: str | None = None, quick: bool = False,
                   bench_out: str = "BENCH_strategies.json") -> dict:
    """Run the measurement harness, persist the calibration JSON, and
    merge the kernel-sweep winners into the strategy benchmark record."""
    from repro import calibrate
    from repro.launch.mesh import make_mesh_from_spec

    mesh = make_mesh_from_spec(mesh_spec) if mesh_spec else None
    calib = calibrate.measure(mesh, quick=quick)
    os.makedirs(os.path.dirname(calibration_out) or ".", exist_ok=True)
    calibrate.save_calibration(calibration_out, calib)
    emit("kernels/calibration/flops_per_second", 0.0,
         f"{calib.flops_per_second:.3e}")
    emit("kernels/calibration/hbm_bytes_per_second", 0.0,
         f"{calib.hbm_bytes_per_second:.3e}")
    for axis, bw in sorted(calib.collective_bytes_per_second.items()):
        emit(f"kernels/calibration/collective/{axis}", 0.0, f"{bw:.3e}")
    pe = calib.kernels.get("pe_conv_grad", {})
    if pe:
        emit("kernels/calibration/pe_conv_vmem_budget", 0.0,
             f"winner={pe['vmem_budget']}_bd={pe['bd']}")

    results = {}
    if os.path.exists(bench_out):
        results = json.load(open(bench_out))
    results["kernels@calibration"] = {
        "hardware": calib.hardware,
        "digest": calib.digest(),
        "calibration_path": calibration_out,
        "flops_per_second": calib.flops_per_second,
        "hbm_bytes_per_second": calib.hbm_bytes_per_second,
        "collective_bytes_per_second": dict(
            calib.collective_bytes_per_second),
        "kernel_sweeps": {k: dict(v) for k, v in calib.kernels.items()},
    }
    with open(bench_out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"calibration {calib.digest()} -> {calibration_out} "
          f"(sweep winners merged into {bench_out})", flush=True)
    return results


if __name__ == "__main__":
    argv = sys.argv[1:]
    cal_only, out_calib, spec, quick, rest, i = \
        False, "results/calibration.json", None, False, [], 0
    while i < len(argv):
        a = argv[i]
        if a == "--calibrate-only":
            cal_only, i = True, i + 1
        elif a == "--calibration-out":
            out_calib, i = argv[i + 1], i + 2
        elif a.startswith("--calibration-out="):
            out_calib, i = a.split("=", 1)[1], i + 1
        elif a == "--mesh":
            spec, i = argv[i + 1], i + 2
        elif a.startswith("--mesh="):
            spec, i = a.split("=", 1)[1], i + 1
        elif a == "--quick":
            quick, i = True, i + 1
        else:
            rest.append(a)
            i += 1
    if cal_only:
        calibrate_only(out_calib, spec, quick,
                       rest[0] if rest else "BENCH_strategies.json")
    else:
        run()
