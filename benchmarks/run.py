"""Benchmark harness — one module per paper table/figure plus the
beyond-paper LM/kernel/roofline analyses.

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import fig1_3, fig2, kernels_bench, lm_overhead, \
        roofline, strategies_bench, table1
    for mod in (table1, fig1_3, fig2, lm_overhead, kernels_bench,
                strategies_bench, roofline):
        print(f"# --- {mod.__name__} ---", flush=True)
        try:
            mod.run()
        except Exception:
            traceback.print_exc()
            print(f"# {mod.__name__} FAILED", flush=True)


if __name__ == "__main__":
    main()
