"""Benchmark timing helpers."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 3,
            reduce: str = "median") -> float:
    """Wall time (µs) of a jitted callable.  ``reduce="min"`` is the
    right statistic when comparing fixed compute graphs on a noisy host:
    the minimum is the least-perturbed execution of the same program."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    red = np.min if reduce == "min" else np.median
    return float(red(ts) * 1e6)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
