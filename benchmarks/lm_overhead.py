"""Beyond-paper: DP per-example-gradient overhead on LM architectures
(reduced configs, CPU wall time + compiled FLOPs).  The production
question: what does ghost/bk DP cost over non-private training?"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core import DPConfig
from repro.core.clipping import dp_gradient, non_dp_gradient
from repro.models.registry import build_model

ARCHS = ["llama3.2-1b", "granite-moe-1b-a400m", "xlstm-125m"]
B, T = 4, 32


def run():
    rng = np.random.RandomState(0)
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.array(rng.randint(0, cfg.vocab, (B, T))),
                 "labels": jnp.array(rng.randint(0, cfg.vocab, (B, T)))}

        nodp = jax.jit(lambda p, b: non_dp_gradient(model.apply, p, b)[0])
        t0 = time_fn(nodp, params, batch)
        emit(f"lm_overhead/{arch}/no_dp", t0, "baseline")
        for s in ("multi", "ghost", "bk"):
            f = jax.jit(lambda p, b, _s=DPConfig(l2_clip=1.0, strategy=s):
                        dp_gradient(model.apply, p, b, cfg=_s)[0])
            t = time_fn(f, params, batch)
            # compiled per-call FLOPs for the analytic comparison
            try:
                fl = jax.jit(
                    lambda p, b, _s=DPConfig(l2_clip=1.0, strategy=s):
                    dp_gradient(model.apply, p, b, cfg=_s)[0]
                ).lower(params, batch).compile().cost_analysis().get("flops")
            except Exception:
                fl = None
            emit(f"lm_overhead/{arch}/{s}", t,
                 f"x{t / t0:.2f}_vs_no_dp;flops={fl}")


if __name__ == "__main__":
    run()
