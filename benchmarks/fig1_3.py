"""Paper Figures 1 & 3: toy-CNN strategy runtimes vs channel rate and
depth, kernel 3 vs 5.  The paper's qualitative claims: crb gains on multi
as channel rate grows (shallow nets) and as kernel size grows; multi gains
with depth."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import DPConfig
from repro.core.clipping import dp_gradient
from repro.models.cnn import toy_cnn_config
from repro.models.registry import build_model

IMG, B, C0 = 64, 8, 8


def run():
    rng = np.random.RandomState(0)
    for kernel in (3, 5):
        for n_layers in (2, 3, 4):
            for rate in (1.0, 1.5, 2.0):
                cfg = toy_cnn_config(n_layers, rate, c0=C0, kernel=kernel,
                                     img=IMG)
                model = build_model(cfg)
                params, _ = model.init(jax.random.PRNGKey(0))
                batch = {"img": jnp.array(rng.randn(B, 3, IMG, IMG),
                                          jnp.float32),
                         "label": jnp.array(rng.randint(0, 10, (B,)))}
                ts = {}
                for s in ("multi", "crb"):
                    f = jax.jit(lambda p, b, _s=DPConfig(l2_clip=1.0,
                                                         strategy=s):
                                dp_gradient(model.apply, p, b, cfg=_s)[0])
                    ts[s] = time_fn(f, params, batch)
                name = f"fig1_3/k{kernel}_L{n_layers}_r{rate}"
                emit(name, ts["crb"],
                     f"crb/multi={ts['crb'] / ts['multi']:.3f}")


if __name__ == "__main__":
    run()
