"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch × shape × mesh) cell, three per-chip time terms on TPU v5e:

    compute    = HLO_FLOPs_per_device / 197e12        [bf16 MXU peak]
    memory     = HLO_bytes_per_device / 819e9         [HBM bandwidth]
    collective = collective_bytes_per_device / 50e9   [ICI per link]

plus MODEL_FLOPS = 6·N·D (train; 2·N·D for serving) with N = active
params, the useful-compute ratio MODEL_FLOPS / (chips · HLO_FLOPs), and
the roofline fraction  (MODEL_FLOPS/chips/peak) / max(terms)  — the score
this framework optimizes in EXPERIMENTS.md §Perf.

``cost_analysis()`` numbers are per-device (verified: doubling the mesh
halves them); collective bytes come from the post-SPMD HLO with
while-loop trip multipliers (see launch/dryrun.py).
"""
from __future__ import annotations

import json
import os

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

CHIPS = {"single": 256, "multi": 512}


def count_params(cfg):
    """(total, active) parameter counts from the abstract init."""
    import jax
    from repro.models.registry import build_model

    model = build_model(cfg)
    box = []

    def only(k):
        p, axes = model.init(k)
        box.append(None)
        return p

    sds = jax.eval_shape(only, jax.random.PRNGKey(0))
    total = active = 0
    for kp, leaf in jax.tree_util.tree_leaves_with_path(sds):
        n = int(np.prod(leaf.shape))
        total += n
        path = jax.tree_util.keystr(kp)
        if ("moe" in path and cfg.n_experts
                and leaf.shape and cfg.n_experts in leaf.shape[:2]
                and "router" not in path and "shared" not in path):
            active += n * cfg.topk // cfg.n_experts
        else:
            active += n
    return total, active


def analyse(record: dict, cfg, n_total: int, n_active: int) -> dict:
    from repro.configs.base import SHAPES
    shape = SHAPES[record["shape"]]
    chips = CHIPS[record["mesh"]]
    # parsed HLO costs carry while-loop trip multipliers (layer scans);
    # XLA's cost_analysis counts loop bodies once, so prefer the parse.
    fl = record.get("flops_parsed") or record.get("flops_per_device") or 0.0
    by = record.get("bytes_parsed") or record.get("bytes_per_device") or 0.0
    co = (record.get("collectives") or {}).get("total_bytes", 0)

    t_compute = fl / PEAK_FLOPS
    t_memory = by / HBM_BW
    t_coll = co / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        model_flops = 2.0 * n_active * tokens

    useful = model_flops / (chips * fl) if fl else 0.0
    t_model = model_flops / chips / PEAK_FLOPS
    dominant = max(terms.values()) or 1e-30
    fraction = t_model / dominant

    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "roofline_fraction": fraction,
        "chips": chips,
    }


def improvement_hint(rec: dict, out: dict) -> str:
    b = out["bottleneck"]
    if b == "compute":
        if out["useful_flops_ratio"] < 0.5:
            return ("compute-bound with low useful-FLOP ratio: cut remat/"
                    "dispatch overhead (gather MoE dispatch, bk instead of "
                    "ghost second backward)")
        return "compute-bound near useful peak: only algorithmic wins left"
    if b == "memory":
        return ("memory-bound: fuse the Gram-norm reduction (Pallas "
                "gram_norm keeps (T,T) tiles in VMEM), larger microbatch, "
                "flash attention for long sequences")
    return ("collective-bound: reshard so ghost-norm contractions stay "
            "local to the TP axis, overlap grad all-reduce with backward, "
            "bf16 collectives")


def run(dryrun_path: str | None = None,
        out_path: str = "results/roofline.json"):
    from benchmarks.common import emit
    from repro.configs import get_config

    if dryrun_path is None:
        for cand in ("results/dryrun_optimized.json",
                     "results/dryrun_baseline.json", "results/dryrun.json"):
            if os.path.exists(cand):
                dryrun_path = cand
                break
        else:
            print("# roofline: no dryrun json found — run "
                  "`python -m repro.launch.dryrun` first")
            return
        print(f"# roofline source: {dryrun_path}")
    if not os.path.exists(dryrun_path):
        print(f"# roofline: {dryrun_path} missing — run "
              f"`python -m repro.launch.dryrun` first")
        return
    records = [r for r in json.load(open(dryrun_path))
               if r.get("status") == "ok"]
    params_cache = {}
    out = []
    for rec in sorted(records, key=lambda r: (r["arch"], r["shape"],
                                              r["mesh"])):
        cfg = get_config(rec["arch"])
        if rec["arch"] not in params_cache:
            params_cache[rec["arch"]] = count_params(cfg)
        n_total, n_active = params_cache[rec["arch"]]
        res = analyse(rec, cfg, n_total, n_active)
        res.update(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                   n_total=n_total, n_active=n_active,
                   hint=improvement_hint(rec, res))
        out.append(res)
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        dom = max(res["t_compute_s"], res["t_memory_s"],
                  res["t_collective_s"])
        emit(name, dom * 1e6,
             f"bottleneck={res['bottleneck']};"
             f"frac={res['roofline_fraction']:.3f};"
             f"useful={res['useful_flops_ratio']:.3f}")
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    run()
