"""Planned (auto) vs fixed strategies: wall time of the full DP-SGD
gradient, emitted to BENCH_strategies.json.

CPU-scaled shapes (the paper's claims are ratio claims); every timed step
returns the gradient pytree so XLA cannot dead-code-eliminate the clipped
sum.  ``auto`` must be no slower than the best fixed strategy on every
config — the planner's whole point is dominating any global choice.

    PYTHONPATH=src python -m benchmarks.strategies_bench [out.json]
"""
from __future__ import annotations

import json
import sys

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core import DPConfig, PrivacyEngine
from repro.core.clipping import dp_gradient
from repro.models.registry import build_model

SETTINGS = {
    "alexnet": dict(kind="cnn", img=64, B=4,
                    strategies=("multi", "crb", "ghost", "bk")),
    "vgg16": dict(kind="cnn", img=32, B=2,
                  strategies=("crb", "ghost", "bk")),
    "llama32_1b": dict(kind="lm", seq=256, B=8,
                       strategies=("multi", "crb", "ghost", "bk")),
}


def _setup(name, s):
    rng = np.random.RandomState(0)
    if s["kind"] == "cnn":
        cfg = get_config(name).replace(img_size=s["img"], n_classes=10)
        model = build_model(cfg)
        batch = {"img": jnp.array(
                     rng.randn(s["B"], 3, s["img"], s["img"]), jnp.float32),
                 "label": jnp.array(rng.randint(0, 10, (s["B"],)))}
    else:
        cfg = get_config("llama3.2-1b").reduced()
        model = build_model(cfg)
        batch = {"tokens": jnp.array(
                     rng.randint(0, cfg.vocab, (s["B"], s["seq"]))),
                 "labels": jnp.array(
                     rng.randint(0, cfg.vocab, (s["B"], s["seq"])))}
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params, batch


def run(out_path: str = "BENCH_strategies.json") -> dict:
    results: dict = {}
    for name, s in SETTINGS.items():
        model, params, batch = _setup(name, s)
        fns = {}
        for strat in s["strategies"]:
            dpc = DPConfig(l2_clip=1.0, strategy=strat)

            def step(p, b, _c=dpc):
                loss, grad, _ = dp_gradient(model.apply, p, b, cfg=_c)
                return loss, grad

            fns[strat] = jax.jit(step)
        # "auto" is timed through the production surface: a PrivacyEngine
        # whose jitted gradient closes over the ExecPlan.
        engine = PrivacyEngine(model.apply, params, batch,
                               dp=DPConfig(l2_clip=1.0, strategy="auto"))
        fns["auto"] = jax.jit(
            lambda p, b, _e=engine: _e.noisy_grad(p, b)[:2])
        # Interleave repeats so host noise hits every strategy equally,
        # then keep each strategy's least-perturbed execution.
        reps = 5 if s["kind"] == "lm" else 3
        times = {k: float("inf") for k in fns}
        for rep in range(reps):
            for strat, f in fns.items():
                t = time_fn(f, params, batch, warmup=2 if rep == 0 else 0,
                            iters=5, reduce="min")
                times[strat] = min(times[strat], t)
        for strat, t in times.items():
            emit(f"strategies/{name}/{strat}", t, "")
        best_fixed = min(v for k, v in times.items() if k != "auto")
        ratio = times["auto"] / best_fixed
        results[name] = {
            "times_us": times,
            "best_fixed_us": best_fixed,
            "auto_vs_best_fixed": ratio,
            "regression": ratio > 1.0,
        }
        if ratio > 1.0:
            print(f"WARNING: auto slower than best fixed strategy on "
                  f"{name} (ratio {ratio:.3f})", flush=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    for name, rec in results.items():
        emit(f"strategies/{name}/auto_vs_best_fixed",
             rec["times_us"]["auto"],
             f"ratio={rec['auto_vs_best_fixed']:.3f}")
    return results


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "BENCH_strategies.json")
