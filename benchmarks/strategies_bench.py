"""Planned (auto) vs fixed strategies: wall time of the full DP-SGD
gradient, emitted to BENCH_strategies.json.

CPU-scaled shapes (the paper's claims are ratio claims); every timed step
returns the gradient pytree so XLA cannot dead-code-eliminate the clipped
sum.  ``auto`` must be no slower than the best fixed strategy on every
config — the planner's whole point is dominating any global choice.

    PYTHONPATH=src python -m benchmarks.strategies_bench [out.json]

``--mesh data:N`` benchmarks the sharded engine instead: auto planned
*with* the mesh (collective-aware plan + explicitly sharded execution)
vs auto planned *without*, on alexnet + llama32_1b, recording which
per-layer decisions the mesh flipped.  On a CPU host the device count is
forced to N before jax initializes.

    PYTHONPATH=src python -m benchmarks.strategies_bench --mesh data:8

A 2D spec (``--mesh data:4,model:2``) runs the same sweep tensor-sharded:
params partitioned over the ``model`` axis via the models' param-axes
trees, entries keyed ``{config}@data:4,model:2`` carrying the per-axis
predicted collective bytes and the calibrated ``planner_verdict``.
"""
from __future__ import annotations

import json
import os
import sys

if __name__ == "__main__":
    # A --mesh data:N run on a CPU host needs N devices before the jax
    # backend initializes.
    from repro.launch.mesh import force_host_device_count_for
    force_host_device_count_for(sys.argv)

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core import ClipPolicy, DPConfig, PrivacyEngine
from repro.core.clipping import dp_gradient
from repro.models.registry import build_model

SETTINGS = {
    "alexnet": dict(kind="cnn", img=64, B=4,
                    strategies=("multi", "crb", "ghost", "bk")),
    "vgg16": dict(kind="cnn", img=32, B=2,
                  strategies=("crb", "ghost", "bk")),
    "llama32_1b": dict(kind="lm", seq=256, B=8,
                       strategies=("multi", "crb", "ghost", "bk")),
}


def _setup(name, s):
    rng = np.random.RandomState(0)
    if s["kind"] == "cnn":
        cfg = get_config(name).replace(img_size=s["img"], n_classes=10)
        model = build_model(cfg)
        batch = {"img": jnp.array(
                     rng.randn(s["B"], 3, s["img"], s["img"]), jnp.float32),
                 "label": jnp.array(rng.randint(0, 10, (s["B"],)))}
    else:
        cfg = get_config("llama3.2-1b").reduced()
        model = build_model(cfg)
        batch = {"tokens": jnp.array(
                     rng.randint(0, cfg.vocab, (s["B"], s["seq"]))),
                 "labels": jnp.array(
                     rng.randint(0, cfg.vocab, (s["B"], s["seq"])))}
    params, axes = model.init(jax.random.PRNGKey(0))
    return model, params, batch, axes


def run(out_path: str = "BENCH_strategies.json") -> dict:
    results: dict = {}
    for name, s in SETTINGS.items():
        model, params, batch, _ = _setup(name, s)
        fns = {}
        for strat in s["strategies"]:
            dpc = DPConfig(l2_clip=1.0, strategy=strat)

            def step(p, b, _c=dpc):
                loss, grad, _ = dp_gradient(model.apply, p, b, cfg=_c)
                return loss, grad

            fns[strat] = jax.jit(step)
        # "auto" is timed through the production surface: a PrivacyEngine
        # whose jitted gradient closes over the ExecPlan.
        engine = PrivacyEngine(model.apply, params, batch,
                               dp=DPConfig(l2_clip=1.0, strategy="auto"))
        fns["auto"] = jax.jit(
            lambda p, b, _e=engine: _e.noisy_grad(p, b)[:2])
        # Interleave repeats so host noise hits every strategy equally,
        # then keep each strategy's least-perturbed execution.
        reps = 5 if s["kind"] == "lm" else 3
        times = {k: float("inf") for k in fns}
        for rep in range(reps):
            for strat, f in fns.items():
                t = time_fn(f, params, batch, warmup=2 if rep == 0 else 0,
                            iters=5, reduce="min")
                times[strat] = min(times[strat], t)
        for strat, t in times.items():
            emit(f"strategies/{name}/{strat}", t, "")
        best_fixed = min(v for k, v in times.items() if k != "auto")
        ratio = times["auto"] / best_fixed
        results[name] = {
            "times_us": times,
            "best_fixed_us": best_fixed,
            "auto_vs_best_fixed": ratio,
            "regression": ratio > 1.0,
        }
        if ratio > 1.0:
            print(f"WARNING: auto slower than best fixed strategy on "
                  f"{name} (ratio {ratio:.3f})", flush=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    for name, rec in results.items():
        emit(f"strategies/{name}/auto_vs_best_fixed",
             rec["times_us"]["auto"],
             f"ratio={rec['auto_vs_best_fixed']:.3f}")
    return results


CLIP_CONFIGS = ("alexnet", "vgg16")


def run_clip_modes(out_path: str = "BENCH_strategies.json") -> dict:
    """Clipping-mode benchmark on the conv-heavy configs: the planned
    engine under flat vs per_layer vs stale clipping, steady state (the
    stale engine is stepped once outside the timer to leave bootstrap).
    Entries merge into the strategy benchmark's JSON under
    ``{config}@clip:{mode}`` keys; stale's fused single-pass plan should
    be no slower than flat — that is the mode's whole point."""
    results = {}
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    for name in CLIP_CONFIGS:
        model, params, batch, _ = _setup(name, SETTINGS[name])
        opt0 = {"step": jnp.zeros(())}

        def ident_opt(grads, state, params, *, lr, weight_decay):
            return params, state

        engines = {
            "flat": PrivacyEngine(
                model.apply, params, batch, optimizer=ident_opt,
                dp=DPConfig(l2_clip=1.0, clipping="flat")),
            "per_layer": PrivacyEngine(
                model.apply, params, batch, optimizer=ident_opt,
                dp=DPConfig(l2_clip=1.0, clipping="per_layer")),
            "stale": PrivacyEngine(
                model.apply, params, batch, optimizer=ident_opt,
                dp=DPConfig(l2_clip=1.0, clipping="stale")),
        }
        # Steady state: step each engine once so the stale engine leaves
        # bootstrap (and every jit is compiled) before the timers run.
        for eng in engines.values():
            eng.private_step(params, opt0, batch)
        # The modes differ by a few percent at most, so the interleaved
        # min needs more samples than the strategy sweep to beat host
        # noise on a shared machine.
        times = {k: float("inf") for k in engines}
        for rep in range(5):
            for mode, eng in engines.items():
                t = time_fn(lambda p, b, _e=eng: _e.private_step(
                                p, opt0, b)[2],
                            params, batch, warmup=1 if rep == 0 else 0,
                            iters=8, reduce="min")
                times[mode] = min(times[mode], t)
        fused = sum(lp.fused
                    for lp in engines["stale"].plan().layers.values())
        for mode, t in times.items():
            key = f"{name}@clip:{mode}"
            results[key] = {
                "times_us": t,
                "vs_flat": t / times["flat"],
                "fused_layers": fused if mode == "stale" else 0,
            }
            emit(f"strategies/{key}", t,
                 f"ratio={t / times['flat']:.3f}")
        if times["stale"] > times["flat"]:
            print(f"WARNING: stale slower than flat on {name} "
                  f"(ratio {times['stale'] / times['flat']:.3f})",
                  flush=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    return results


def run_attn(out_path: str = "BENCH_strategies.json") -> dict:
    """Attention-block realization benchmark: the block-level ghost norm
    (layer-local recompute + Gram-style reduction; per-example attention
    gradients never materialized) vs the materializing ``pe`` baseline on
    the same ``dp_attn``-tapped model, plus the planned engine surface
    (whose plan should pick ghost for the attention blocks).  Entries
    merge into the strategy benchmark's JSON under ``{config}@dp_attn``;
    ghost no slower than pe is the acceptance bar."""
    from repro.core import clipped_grad_sum

    results = {}
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    s = SETTINGS["llama32_1b"]
    rng = np.random.RandomState(0)
    cfg = get_config("llama3.2-1b").reduced().replace(dp_attn=True)
    model = build_model(cfg)
    batch = {"tokens": jnp.array(
                 rng.randint(0, cfg.vocab, (s["B"], s["seq"]))),
             "labels": jnp.array(
                 rng.randint(0, cfg.vocab, (s["B"], s["seq"])))}
    params, _ = model.init(jax.random.PRNGKey(0))

    def norm_fn(method):
        def f(p, b):
            _, gsum, _ = clipped_grad_sum(model.apply, p, b, l2_clip=1.0,
                                          strategy="ghost",
                                          attn_norm=method)
            return gsum
        return jax.jit(f)

    engine = PrivacyEngine(model.apply, params, batch,
                           dp=DPConfig(l2_clip=1.0, strategy="auto"))
    fns = {"attn_ghost": norm_fn("ghost"),
           "attn_pe": norm_fn("pe"),
           "auto": jax.jit(lambda p, b, _e=engine: _e.noisy_grad(p, b)[:2])}
    times = {k: float("inf") for k in fns}
    for rep in range(3):
        for k, f in fns.items():
            t = time_fn(f, params, batch, warmup=2 if rep == 0 else 0,
                        iters=3, reduce="min")
            times[k] = min(times[k], t)
    plan = engine.plan()
    attn_methods = sorted({lp.norm_method
                           for lp in plan.layers.values()
                           if lp.kind == "attn"})
    ratio = times["attn_ghost"] / times["attn_pe"]
    key = "llama32_1b@dp_attn"
    results[key] = {
        "batch": s["B"], "seq": s["seq"],
        "times_us": times,
        "ghost_vs_materialize": ratio,
        "planned_attn_methods": attn_methods,
        "regression": ratio > 1.0,
    }
    for k, t in times.items():
        emit(f"strategies/{key}/{k}", t, "")
    emit(f"strategies/{key}/ghost_vs_materialize", times["attn_ghost"],
         f"ratio={ratio:.3f} planned={','.join(attn_methods)}")
    if ratio > 1.0:
        print(f"WARNING: attn ghost norm slower than materialize "
              f"(ratio {ratio:.3f})", flush=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    return results


MESH_CONFIGS = ("alexnet", "llama32_1b")


def run_mesh(spec: str, out_path: str = "BENCH_strategies.json",
             calibration: str | None = None) -> dict:
    """Sharded-engine benchmark: auto planned with the mesh (collective-
    aware costs + explicit NamedShardings) vs auto planned without, same
    global batch.  Entries merge into the strategy benchmark's JSON under
    ``{config}@{spec}`` keys.

    Each config then closes the calibration loop: the harness measures
    the wire on this mesh, the observed ``auto_mesh`` step is folded back
    via ``Calibration.retimed``, the engine re-plans under the measured
    constants, and the record carries the planner's calibrated verdict —
    either the plan flips to something faster, or the cost model proves
    unsharded right and the apparent "regression" was priced fiction from
    the analytic wire constant.  ``--calibration PATH`` pre-registers a
    saved blob (e.g. from ``kernels_bench --calibrate-only``) so the
    *initial* mesh plan is already calibrated."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import calibrate
    from repro.core import costmodel
    from repro.launch.mesh import make_mesh_from_spec
    from repro.launch.sharding import batch_sharding, param_sharding

    mesh = make_mesh_from_spec(spec)
    axes = costmodel.mesh_axes(mesh)
    d = costmodel.mesh_data_size(axes)
    if calibration:
        calib = calibrate.load_or_fallback(calibration, mesh=axes)
        if calib is not None:
            calibrate.register(calib)
            print(f"[calibrate] registered {calib.digest()} "
                  f"(source={calib.source})", flush=True)
    results = {}
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    for name in MESH_CONFIGS:
        s = dict(SETTINGS[name])
        s["B"] = -(-s["B"] // d) * d       # round up to a multiple of d
        model, params, batch, paxes = _setup(name, s)
        eng0 = PrivacyEngine(model.apply, params, batch,
                             dp=DPConfig(l2_clip=1.0, strategy="auto"))
        # param_axes makes a model axis real: on a 2D spec the mesh
        # engines run tensor-sharded (psum'd partial Grams over `model`);
        # on a pure-data mesh the axes tree is inert.
        eng1 = PrivacyEngine(model.apply, params, batch,
                             dp=DPConfig(l2_clip=1.0, strategy="auto"),
                             mesh=mesh, param_axes=paxes)
        repl = NamedSharding(mesh, P())
        bsh = batch_sharding(batch, mesh)
        # On a 2D spec the timed boundary matches production layout:
        # params in (and the gradient out) partitioned over `model`.
        psh = (param_sharding(paxes, mesh, shapes_tree=params)
               if costmodel.mesh_model_axes(axes) else repl)
        fns = {
            "auto": jax.jit(lambda p, b, _e=eng0: _e.noisy_grad(p, b)[:2]),
            "auto_mesh": jax.jit(
                lambda p, b, _e=eng1: _e.noisy_grad(p, b)[:2],
                in_shardings=(psh, bsh), out_shardings=(repl, psh)),
        }
        times = {k: float("inf") for k in fns}
        for rep in range(3):
            for k, f in fns.items():
                t = time_fn(f, params, batch, warmup=2 if rep == 0 else 0,
                            iters=3, reduce="min")
                times[k] = min(times[k], t)
        p0, p1 = eng0.plan(), eng1.plan()
        s0, s1 = p0.sum_methods(), p1.sum_methods()
        flips = {n: {"without": [p0.layers[n].norm_method, s0[n]],
                     "with": [p1.layers[n].norm_method, s1[n]]}
                 for n in p0.layers
                 if (p0.layers[n].norm_method, s0[n])
                 != (p1.layers[n].norm_method, s1[n])}
        key = f"{name}@{spec}"
        results[key] = {
            "devices": d,
            "batch": s["B"],
            "times_us": times,
            "mesh_vs_nomesh": times["auto_mesh"] / times["auto"],
            "plan_flips": flips,
            "predicted_coll_mb_per_dev": p1.total_coll_bytes / 2**20,
            "predicted_coll_mb_per_dev_by_axis": {
                a: b / 2**20 for a, b in p1.total_coll_bytes_by_axis},
        }
        emit(f"strategies/{key}/auto_mesh", times["auto_mesh"],
             f"ratio={results[key]['mesh_vs_nomesh']:.3f} "
             f"flips={len(flips)}")

        # --- close the calibration loop: measure the wire, fold the
        # observed auto_mesh step back into the calibration, re-plan
        # under the measured constants, and record the verdict.
        calib0 = calibrate.lookup(axes)
        if calib0 is None:
            calib0 = calibrate.measure(mesh, quick=True)
        pred_s = costmodel.predicted_step_seconds(p1, calib0)
        calib1 = calib0.retimed(predicted_s=pred_s,
                                measured_s=times["auto_mesh"] / 1e6,
                                coll_bytes=p1.total_coll_bytes,
                                coll_bytes_by_axis=p1
                                .total_coll_bytes_by_axis)
        calibrate.register(calib1)
        eng2 = PrivacyEngine(model.apply, params, batch,
                             dp=DPConfig(l2_clip=1.0, strategy="auto"),
                             mesh=mesh, param_axes=paxes,
                             calibration=calib1)
        p2 = eng2.plan()
        verdict = costmodel.planner_verdict(p2, p0, calib1)
        plan_changed = p2.describe() != p1.describe()
        if plan_changed:
            f2 = jax.jit(lambda p, b, _e=eng2: _e.noisy_grad(p, b)[:2],
                         in_shardings=(psh, bsh), out_shardings=(repl, psh))
            t2 = time_fn(f2, params, batch, warmup=2, iters=3,
                         reduce="min")
        else:
            t2 = times["auto_mesh"]
        ratio_cal = t2 / times["auto"]
        results[key].update({
            "calibration": calib1.digest(),
            "planner_verdict": verdict,
            # per-axis view behind the verdict: what the calibrated plan
            # says each mesh axis carries, priced at that axis's wire
            "calibrated_coll_mb_per_dev_by_axis": {
                a: b / 2**20 for a, b in p2.total_coll_bytes_by_axis},
            "calibrated_plan_changed": plan_changed,
            "times_us_calibrated": t2,
            "mesh_vs_nomesh_calibrated": ratio_cal,
            "predicted_step_s": {
                "auto": costmodel.predicted_step_seconds(p0, calib1),
                "auto_mesh": costmodel.predicted_step_seconds(p2, calib1),
            },
            # only a real regression if the calibrated planner still
            # claims sharded wins while the measurement disagrees
            "regression": verdict == "sharded" and ratio_cal > 1.0,
        })
        emit(f"strategies/{key}/calibrated", t2,
             f"verdict={verdict} ratio={ratio_cal:.3f} "
             f"plan_changed={plan_changed} calib={calib1.digest()}")
        if results[key]["regression"]:
            print(f"WARNING: calibrated planner claims sharded wins on "
                  f"{key} but measurement disagrees "
                  f"(ratio {ratio_cal:.3f})", flush=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    argv = sys.argv[1:]
    spec, clip_modes, calib_path, rest, i = None, False, None, [], 0
    dp_attn = False
    while i < len(argv):
        a = argv[i]
        if a == "--dp-attn":
            dp_attn, i = True, i + 1
        elif a == "--mesh":
            if i + 1 >= len(argv):
                raise SystemExit("--mesh requires a spec, e.g. "
                                 "--mesh data:8")
            spec, i = argv[i + 1], i + 2
        elif a.startswith("--mesh="):
            spec, i = a.split("=", 1)[1], i + 1
        elif a == "--calibration":
            calib_path, i = argv[i + 1], i + 2
        elif a.startswith("--calibration="):
            calib_path, i = a.split("=", 1)[1], i + 1
        elif a == "--clip-modes":
            clip_modes, i = True, i + 1
        else:
            rest.append(a)
            i += 1
    out = rest[0] if rest else "BENCH_strategies.json"
    if spec:
        run_mesh(spec, out, calibration=calib_path)
    elif clip_modes:
        run_clip_modes(out)
    elif dp_attn:
        run_attn(out)
    else:
        run(out)
