"""Paper Table 1: AlexNet / VGG16 runtimes per strategy.

CPU-scaled reproduction: 20 batches at the paper's batch sizes are
infeasible on one CPU core at 3x256x256, so we run reduced image sizes and
report *ratios between strategies* — the paper's claims are ratio claims
(crb ~15x faster than naive on AlexNet; multi ~ crb within 2x on VGG16).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core import DPConfig
from repro.core.clipping import dp_gradient, non_dp_gradient
from repro.models.registry import build_model

SETTINGS = {  # arch -> (img, batch, strategies)
    "alexnet": (96, 8, ("naive", "multi", "crb", "ghost", "bk", "auto")),
    "vgg16": (64, 4, ("multi", "crb", "ghost", "bk", "auto")),  # no naive
}


def run():
    rng = np.random.RandomState(0)
    for arch, (img, B, strategies) in SETTINGS.items():
        cfg = get_config(arch).replace(img_size=img, n_classes=100)
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        batch = {"img": jnp.array(rng.randn(B, 3, img, img), jnp.float32),
                 "label": jnp.array(rng.randint(0, 100, (B,)))}

        nodp = jax.jit(lambda p, b: non_dp_gradient(model.apply, p, b)[0])
        t0 = time_fn(nodp, params, batch)
        emit(f"table1/{arch}/no_dp", t0, "baseline")

        for s in strategies:
            dpc = DPConfig(l2_clip=1.0, strategy=s)
            f = jax.jit(lambda p, b, _s=dpc: dp_gradient(
                model.apply, p, b, cfg=_s)[0])
            t = time_fn(f, params, batch)
            emit(f"table1/{arch}/{s}", t, f"x{t / t0:.2f}_vs_no_dp")


if __name__ == "__main__":
    run()
