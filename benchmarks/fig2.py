"""Paper Figure 2: runtime vs batch size per strategy (toy CNN, kernel 5,
3 layers, wide first layer).  Claim: naive/multi scale linearly in B; crb
flattens (sub-linear slope) at larger batches."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import DPConfig
from repro.core.clipping import dp_gradient
from repro.models.cnn import toy_cnn_config
from repro.models.registry import build_model

IMG = 48


def run():
    rng = np.random.RandomState(0)
    cfg = toy_cnn_config(3, 1.0, c0=32, kernel=5, img=IMG)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    prev = {}
    for B in (2, 4, 8, 16):
        batch = {"img": jnp.array(rng.randn(B, 3, IMG, IMG), jnp.float32),
                 "label": jnp.array(rng.randint(0, 10, (B,)))}
        for s in ("naive", "multi", "crb"):
            f = jax.jit(lambda p, b, _s=DPConfig(l2_clip=1.0, strategy=s):
                        dp_gradient(model.apply, p, b, cfg=_s)[0])
            t = time_fn(f, params, batch)
            slope = f"slope_vs_halfB={t / prev[s]:.2f}" if s in prev else ""
            emit(f"fig2/B{B}/{s}", t, slope)
            prev[s] = t


if __name__ == "__main__":
    run()
