"""Model registry: config -> model object."""
from __future__ import annotations

from repro.configs.base import ModelConfig


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm", "ssm", "hybrid"):
        from repro.models.lm import TransformerLM
        return TransformerLM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    if cfg.family == "cnn":
        from repro.models.cnn import CNN
        return CNN(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
