"""Convolutional networks — the paper's own benchmark models.

AlexNet and VGG16 (Table 1), and the parametric "toy" CNNs of Figures 1–3
(first-layer channels c0, channel rate r, kernel size K, ReLU after each
conv, max-pool every 2 convs).  No batch normalization — the paper
excludes it because it mixes examples (per-example gradients become
ill-defined); dropout is likewise omitted (noted deviation, irrelevant to
gradient benchmarking).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.tapper import Tapper
from repro.models import common as cm

ALEXNET = [  # (out_ch, kernel, stride, pad, pool_after)
    (64, 11, 4, 2, True), (192, 5, 1, 2, True), (384, 3, 1, 1, False),
    (256, 3, 1, 1, False), (256, 3, 1, 1, True)]
VGG16 = [(64, 3, 1, 1, False), (64, 3, 1, 1, True),
         (128, 3, 1, 1, False), (128, 3, 1, 1, True),
         (256, 3, 1, 1, False), (256, 3, 1, 1, False), (256, 3, 1, 1, True),
         (512, 3, 1, 1, False), (512, 3, 1, 1, False), (512, 3, 1, 1, True),
         (512, 3, 1, 1, False), (512, 3, 1, 1, False), (512, 3, 1, 1, True)]


def _maxpool(x, k=2, s=2):
    return lax.reduce_window(x, -jnp.inf, lax.max, (1, 1, k, k),
                             (1, 1, s, s), "VALID")


def _conv_plan(cfg: ModelConfig):
    if cfg.cnn_arch == "alexnet":
        plan, pool_k, pool_s = ALEXNET, 3, 2
        fcs = (4096, 4096)
    elif cfg.cnn_arch == "vgg16":
        plan, pool_k, pool_s = VGG16, 2, 2
        fcs = (4096, 4096)
    else:  # toy
        plan = []
        for i, ch in enumerate(cfg.cnn_channels):
            pool = (i % 2 == 1)
            plan.append((ch, cfg.cnn_kernel, 1, 0, pool))
        pool_k, pool_s = 2, 2
        fcs = ()
    return plan, pool_k, pool_s, fcs


def _spatial_after(cfg, plan, pool_k, pool_s):
    h = cfg.img_size
    for (ch, k, s, p, pool) in plan:
        h = (h + 2 * p - k) // s + 1
        if pool:
            h = (h - pool_k) // pool_s + 1
    return h


class CNN:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan, self.pool_k, self.pool_s, self.fcs = _conv_plan(cfg)

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, len(self.plan) + len(self.fcs) + 1)
        tree = {}
        cin = 3
        for i, (ch, k, s, p, pool) in enumerate(self.plan):
            tree[f"conv{i}"] = {
                "w": cm.mk(ks[i], (ch, cin, k, k), ("mlp", None, None,
                                                    "conv_k"),
                           scale=(cin * k * k) ** -0.5, dtype=cfg.jdtype),
                "b": cm.mk(ks[i], (ch,), ("mlp",), dist="zeros",
                           dtype=cfg.jdtype)}
            cin = ch
        side = _spatial_after(cfg, self.plan, self.pool_k, self.pool_s)
        feat = cin * side * side
        dims = (feat,) + self.fcs + (cfg.n_classes,)
        for j in range(len(dims) - 1):
            tree[f"fc{j}"] = {
                "w": cm.mk(ks[len(self.plan) + j], (dims[j], dims[j + 1]),
                           ("embed", "mlp"), scale=dims[j] ** -0.5,
                           dtype=cfg.jdtype),
                "b": cm.mk(ks[len(self.plan) + j], (dims[j + 1],), ("mlp",),
                           dist="zeros", dtype=cfg.jdtype)}
        return cm.split_tree(tree)

    def features(self, params, img, tp: Tapper):
        h = img
        for i, (ch, k, s, p, pool) in enumerate(self.plan):
            h = tp.conv(f"conv{i}", h, params[f"conv{i}"]["w"],
                        params[f"conv{i}"]["b"], stride=s, padding=p)
            h = jax.nn.relu(h)
            if pool:
                h = lax.reduce_window(h, -jnp.inf, lax.max,
                                      (1, 1, self.pool_k, self.pool_k),
                                      (1, 1, self.pool_s, self.pool_s),
                                      "VALID")
        return h.reshape(h.shape[0], -1)

    def apply(self, params, batch, tp: Tapper):
        h = self.features(params, batch["img"].astype(self.cfg.jdtype), tp)
        n_fc = len(self.fcs) + 1
        for j in range(n_fc):
            h = tp.dense(f"fc{j}", h, params[f"fc{j}"]["w"],
                         params[f"fc{j}"]["b"])
            if j < n_fc - 1:
                h = jax.nn.relu(h)
        logp = jax.nn.log_softmax(h.astype(jnp.float32))
        return -jnp.take_along_axis(logp, batch["label"][:, None], 1)[:, 0]

    def train_input_specs(self, shape: ShapeSpec | None = None,
                          batch: int | None = None):
        cfg = self.cfg
        B = batch or (shape.global_batch if shape else 8)
        return {"img": jax.ShapeDtypeStruct((B, 3, cfg.img_size,
                                             cfg.img_size), jnp.float32),
                "label": jax.ShapeDtypeStruct((B,), jnp.int32)}


def toy_cnn_config(n_layers: int, channel_rate: float, *, c0: int = 25,
                   kernel: int = 3, img: int = 256,
                   n_classes: int = 10) -> ModelConfig:
    """The paper's Fig-1/2/3 toy CNNs."""
    chans = tuple(int(round(c0 * channel_rate ** i)) for i in range(n_layers))
    return ModelConfig(
        name=f"toy{n_layers}_r{channel_rate}", family="cnn", n_layers=n_layers,
        d_model=0, n_heads=0, n_kv=0, d_ff=0, vocab=0, cnn_arch="toy",
        cnn_channels=chans, cnn_kernel=kernel, img_size=img,
        n_classes=n_classes)
