"""Shared model-building blocks: parameter builder with logical sharding
axes, norms (tapped affines), RoPE, and per-example losses."""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.tapper import Tapper
from repro.launch.sharding import shard_act


# ---------------------------------------------------------------------------
# Parameter builder: every param leaf is a Pm(value, logical_axes) pair until
# `split_tree` separates them.


@dataclasses.dataclass
class Pm:
    value: object
    axes: tuple


def is_pm(x):
    return isinstance(x, Pm)


def mk(key, shape, axes, *, scale=None, dist="normal", dtype=jnp.float32):
    assert len(shape) == len(axes), (shape, axes)
    if dist == "zeros":
        return Pm(jnp.zeros(shape, dtype), axes)
    if dist == "ones":
        return Pm(jnp.ones(shape, dtype), axes)
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0] if len(shape) else 1.0)
    return Pm((jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype),
              axes)


def split_tree(tree):
    """-> (params, axes) from a Pm tree."""
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=is_pm)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_pm)
    return params, axes


def stack_layers(key, n: int, layer_init):
    """Initialize `n` layers and stack each leaf with a leading 'layer' axis."""
    trees = [layer_init(k) for k in jax.random.split(key, n)]
    def stack(*ps):
        return Pm(jnp.stack([p.value for p in ps]), ("layer",) + ps[0].axes)
    return jax.tree.map(stack, *trees, is_leaf=is_pm)


# ---------------------------------------------------------------------------
# Norms (affine parts are tapped so their per-example grads are covered)


def rmsnorm(tp: Tapper, name: str, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    nx = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    nx = nx.astype(x.dtype)
    if p is None:
        return nx
    return tp.scale(name, nx, p["g"])


def layernorm(tp: Tapper, name: str, p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    nx = (xf - mu) * jax.lax.rsqrt(jnp.var(xf, -1, keepdims=True) + eps)
    nx = nx.astype(x.dtype)
    if p is None:  # non-parametric (OLMo)
        return nx
    return tp.scale(name, nx, p["g"], p.get("b"))


def norm_init(key, d: int, kind: str, dtype=jnp.float32):
    if kind == "layernorm_np":
        return None
    if kind == "layernorm":
        return {"g": mk(key, (d,), ("embed",), dist="ones", dtype=dtype),
                "b": mk(key, (d,), ("embed",), dist="zeros", dtype=dtype)}
    return {"g": mk(key, (d,), ("embed",), dist="ones", dtype=dtype)}


def apply_norm(tp, name, p, x, kind: str):
    if kind in ("layernorm", "layernorm_np"):
        return layernorm(tp, name, p, x)
    return rmsnorm(tp, name, p, x)


# ---------------------------------------------------------------------------
# Rotary embeddings


def rope_angles(positions, dim: int, theta: float):
    """positions (..., T) -> cos/sin (..., T, dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, T, H, hd); cos/sin (B, T, hd/2) or (T, hd/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    cos, sin = cos.astype(x.dtype), sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


# ---------------------------------------------------------------------------
# Losses


def per_example_xent(logits, labels, mask=None, vocab_valid: int | None = None):
    """Per-example mean cross entropy.  logits (B, T, V) fp-any; labels (B, T).

    ``vocab_valid`` masks padded vocabulary rows out of the softmax.
    """
    lg = logits.astype(jnp.float32)
    if vocab_valid is not None and vocab_valid < lg.shape[-1]:
        neg = jnp.full((lg.shape[-1] - vocab_valid,), -1e30, jnp.float32)
        lg = lg.at[..., vocab_valid:].set(neg)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll, axis=-1)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m, axis=-1) / jnp.maximum(jnp.sum(m, axis=-1), 1.0)


def shard_hidden(x):
    return shard_act(x, "batch", "seq", "embed")
