"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM, sLSTM).

Per-example gradient coverage: all projections (in/out, conv, gates, qkv)
are tapped denses/convs; the few parameters living *inside* the recurrence
(Mamba2's A_log/dt_bias/D, sLSTM's recurrent R and gate biases) go through
the generic ``local_vjp`` kind — the layer-local VJP is re-run per example
under vmap, which is cheap because those parameter counts are tiny.

Decode paths (``*_step``) carry explicit recurrent state and need no taps.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.tapper import Tapper
from repro.models import common as cm
from repro.models.mlp import mlp_apply, mlp_init

HEADDIM = 64


# ---------------------------------------------------------------------------
# Mamba2 (SSD): h_t = exp(dt·A) h_{t-1} + dt·(x_t ⊗ B_t);  y_t = h_t·C_t + D·x_t


def _ssd_scan(params, xh, Bm, Cm, dt_raw):
    """xh (B,T,nh,hd); Bm/Cm (B,T,ds); dt_raw (B,T,nh) -> y (B,T,nh,hd)."""
    A = -jnp.exp(params["A_log"].astype(jnp.float32))            # (nh,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,T,nh)
    decay = jnp.exp(dt * A)                                       # (B,T,nh)
    B_, T = xh.shape[0], xh.shape[1]
    nh, hd = xh.shape[2], xh.shape[3]
    ds = Bm.shape[-1]

    def step(h, inp):
        x_t, b_t, c_t, dec_t, dt_t = inp
        # h (B,nh,hd,ds)
        h = dec_t[:, :, None, None] * h + \
            (dt_t[:, :, None] * x_t)[..., None] * b_t[:, None, None, :]
        y = jnp.einsum("bnhs,bs->bnh", h, c_t)
        return h, y

    h0 = jnp.zeros((B_, nh, hd, ds), jnp.float32)
    xs = (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(decay, 1, 0), jnp.moveaxis(dt, 1, 0))
    _, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                    # (B,T,nh,hd)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    return y.astype(xh.dtype)


def mamba2_init(key, d_model, *, d_state, expand=2, d_conv=4,
                dtype=jnp.float32):
    di = expand * d_model
    nh = di // HEADDIM
    conv_dim = di + 2 * d_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": {"w": cm.mk(ks[0], (d_model,
                                       2 * di + 2 * d_state + nh),
                               ("embed", "mlp"), dtype=dtype)},
        "conv": {"w": cm.mk(ks[1], (conv_dim, 1, d_conv),
                            ("mlp", None, "conv_k"),
                            scale=1.0 / math.sqrt(d_conv), dtype=dtype),
                 "b": cm.mk(ks[2], (conv_dim,), ("mlp",), dist="zeros",
                            dtype=dtype)},
        "ssd": {"A_log": cm.mk(ks[3], (nh,), (None,), dist="zeros",
                               dtype=jnp.float32),
                "dt_bias": cm.mk(ks[4], (nh,), (None,), dist="zeros",
                                 dtype=jnp.float32),
                "D": cm.mk(ks[5], (nh,), (None,), dist="ones",
                           dtype=jnp.float32)},
        "norm": {"g": cm.mk(ks[3], (di,), ("mlp",), dist="ones", dtype=dtype)},
        "out_proj": {"w": cm.mk(ks[5], (di, d_model), ("mlp", "embed"),
                                dtype=dtype)},
    }


def mamba2_apply(tp: Tapper, name: str, p, x, *, d_state, expand=2, d_conv=4):
    B, T, D = x.shape
    di = expand * D
    nh = di // HEADDIM
    zxbcdt = tp.dense(f"{name}/in_proj", x, p["in_proj"]["w"])
    z, xc, Bm, Cm, dt_raw = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + d_state, 2 * di + 2 * d_state], axis=-1)

    # causal depthwise conv over time on (xc, B, C)
    conv_in = jnp.concatenate([xc, Bm, Cm], -1)          # (B,T,conv_dim)
    conv_dim = conv_in.shape[-1]
    ci = jnp.moveaxis(conv_in, 1, 2)                      # (B,conv_dim,T)
    ci = jnp.pad(ci, ((0, 0), (0, 0), (d_conv - 1, 0)))
    co = tp.conv(f"{name}/conv", ci, p["conv"]["w"], p["conv"]["b"],
                 groups=conv_dim)
    co = jax.nn.silu(jnp.moveaxis(co, 1, 2))              # (B,T,conv_dim)
    xc, Bm, Cm = jnp.split(co, [di, di + d_state], axis=-1)

    xh = xc.reshape(B, T, nh, HEADDIM)
    y = tp.local_vjp(f"{name}/ssd", _ssd_scan, p["ssd"], xh, Bm, Cm, dt_raw)
    y = y.reshape(B, T, di)
    y = cm.rmsnorm(tp, f"{name}/norm", p["norm"], y * jax.nn.silu(z))
    return tp.dense(f"{name}/out_proj", y, p["out_proj"]["w"])


def mamba2_state(batch, d_model, *, d_state, expand=2, d_conv=4,
                 dtype=jnp.float32):
    di = expand * d_model
    nh = di // HEADDIM
    conv_dim = di + 2 * d_state
    return {"h": jnp.zeros((batch, nh, HEADDIM, d_state), jnp.float32),
            "conv": jnp.zeros((batch, conv_dim, d_conv - 1), dtype)}


def mamba2_step(p, state, x_t, *, d_state, expand=2, d_conv=4):
    """x_t (B, D) -> (y_t, state).  O(1) per token."""
    B, D = x_t.shape
    di = expand * D
    nh = di // HEADDIM
    zxbcdt = x_t @ p["in_proj"]["w"]
    z, xc, Bm, Cm, dt_raw = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + d_state, 2 * di + 2 * d_state], axis=-1)
    conv_in = jnp.concatenate([xc, Bm, Cm], -1)           # (B,conv_dim)
    hist = jnp.concatenate([state["conv"], conv_in[:, :, None]], -1)
    w = p["conv"]["w"][:, 0, :]                            # (conv_dim,K)
    co = jnp.einsum("bck,ck->bc", hist, w) + p["conv"]["b"]
    co = jax.nn.silu(co)
    xc, Bm, Cm = jnp.split(co, [di, di + d_state], axis=-1)
    xh = xc.reshape(B, nh, HEADDIM).astype(jnp.float32)
    A = -jnp.exp(p["ssd"]["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["ssd"]["dt_bias"])
    dec = jnp.exp(dt * A)
    h = dec[:, :, None, None] * state["h"] + \
        (dt[:, :, None] * xh)[..., None] * Bm.astype(jnp.float32)[:, None, None, :]
    y = jnp.einsum("bnhs,bs->bnh", h, Cm.astype(jnp.float32))
    y = y + p["ssd"]["D"][None, :, None] * xh
    y = y.reshape(B, di).astype(x_t.dtype)
    # gated rmsnorm
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         ).astype(y.dtype) * p["norm"]["g"]
    y = y @ p["out_proj"]["w"]
    new_conv = hist[:, :, 1:]
    return y, {"h": h, "conv": new_conv.astype(state["conv"].dtype)}


# ---------------------------------------------------------------------------
# xLSTM — mLSTM (matrix memory, parallelizable) & sLSTM (scalar memory,
# recurrent weights)


def mlstm_init(key, d_model, *, expand=2, d_conv=4, n_heads=4,
               dtype=jnp.float32):
    di = expand * d_model
    ks = jax.random.split(key, 9)
    return {
        "up": {"w": cm.mk(ks[0], (d_model, 2 * di), ("embed", "mlp"),
                          dtype=dtype)},
        "conv": {"w": cm.mk(ks[1], (di, 1, d_conv), ("mlp", None, "conv_k"),
                            scale=1.0 / math.sqrt(d_conv), dtype=dtype),
                 "b": cm.mk(ks[2], (di,), ("mlp",), dist="zeros", dtype=dtype)},
        "wq": {"w": cm.mk(ks[3], (di, di), ("mlp", "heads"), dtype=dtype)},
        "wk": {"w": cm.mk(ks[4], (di, di), ("mlp", "heads"), dtype=dtype)},
        "wv": {"w": cm.mk(ks[5], (di, di), ("mlp", "heads"), dtype=dtype)},
        "wif": {"w": cm.mk(ks[6], (di, 2 * n_heads), ("mlp", None),
                           scale=0.1, dtype=dtype),
                "b": cm.mk(ks[7], (2 * n_heads,), (None,), dist="zeros",
                           dtype=dtype)},
        "norm": {"g": cm.mk(ks[7], (di,), ("mlp",), dist="ones", dtype=dtype)},
        "down": {"w": cm.mk(ks[8], (di, d_model), ("mlp", "embed"),
                            dtype=dtype)},
    }


def _mlstm_scan(q, k, v, i_pre, f_pre):
    """Stabilized mLSTM recurrence.  q,k,v (B,T,H,hd); gates (B,T,H)."""
    B, T, H, hd = q.shape

    def step(carry, inp):
        C, n, m = carry                     # C (B,H,hd,hd), n (B,H,hd), m (B,H)
        qt, kt, vt, it, ft = inp
        logf = -jax.nn.softplus(-ft)        # log sigmoid(f)
        m_new = jnp.maximum(logf + m, it)
        fg = jnp.exp(logf + m - m_new)
        ig = jnp.exp(it - m_new)
        C = fg[..., None, None] * C + ig[..., None, None] * \
            (kt[..., :, None] * vt[..., None, :])
        n = fg[..., None] * n + ig[..., None] * kt
        num = jnp.einsum("bhkv,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
        h = num / den[..., None]
        return (C, n, m_new), h

    init = (jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.zeros((B, H), jnp.float32))
    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (q, k, v, i_pre, f_pre))
    _, hs = lax.scan(step, init, xs)
    return jnp.moveaxis(hs, 0, 1)           # (B,T,H,hd)


def mlstm_apply(tp: Tapper, name: str, p, x, *, expand=2, d_conv=4,
                n_heads=4):
    B, T, D = x.shape
    di = expand * D
    hd = di // n_heads
    up = tp.dense(f"{name}/up", x, p["up"]["w"])
    xin, z = jnp.split(up, 2, -1)
    ci = jnp.moveaxis(xin, 1, 2)
    ci = jnp.pad(ci, ((0, 0), (0, 0), (d_conv - 1, 0)))
    co = tp.conv(f"{name}/conv", ci, p["conv"]["w"], p["conv"]["b"],
                 groups=di)
    xc = jax.nn.silu(jnp.moveaxis(co, 1, 2))
    q = tp.dense(f"{name}/wq", xc, p["wq"]["w"]).reshape(B, T, n_heads, hd)
    k = tp.dense(f"{name}/wk", xc, p["wk"]["w"]).reshape(B, T, n_heads, hd)
    k = k / math.sqrt(hd)
    v = tp.dense(f"{name}/wv", xin, p["wv"]["w"]).reshape(B, T, n_heads, hd)
    gates = tp.dense(f"{name}/wif", xin, p["wif"]["w"], p["wif"]["b"])
    i_pre, f_pre = jnp.split(gates, 2, -1)
    h = _mlstm_scan(q, k, v, i_pre, f_pre).reshape(B, T, di).astype(x.dtype)
    h = cm.rmsnorm(tp, f"{name}/norm", p["norm"], h) * jax.nn.silu(z)
    return tp.dense(f"{name}/down", h, p["down"]["w"])


def mlstm_state(batch, d_model, *, expand=2, d_conv=4, n_heads=4,
                dtype=jnp.float32):
    di = expand * d_model
    hd = di // n_heads
    return {"C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
            "m": jnp.zeros((batch, n_heads), jnp.float32),
            "conv": jnp.zeros((batch, di, d_conv - 1), dtype)}


def mlstm_step(p, state, x_t, *, expand=2, d_conv=4, n_heads=4):
    B, D = x_t.shape
    di = expand * D
    hd = di // n_heads
    up = x_t @ p["up"]["w"]
    xin, z = jnp.split(up, 2, -1)
    hist = jnp.concatenate([state["conv"], xin[:, :, None]], -1)
    w = p["conv"]["w"][:, 0, :]
    xc = jax.nn.silu(jnp.einsum("bck,ck->bc", hist, w) + p["conv"]["b"])
    q = (xc @ p["wq"]["w"]).reshape(B, n_heads, hd).astype(jnp.float32)
    k = (xc @ p["wk"]["w"]).reshape(B, n_heads, hd).astype(jnp.float32)
    k = k / math.sqrt(hd)
    v = (xin @ p["wv"]["w"]).reshape(B, n_heads, hd).astype(jnp.float32)
    gates = (xin @ p["wif"]["w"] + p["wif"]["b"]).astype(jnp.float32)
    it, ft = jnp.split(gates, 2, -1)
    logf = -jax.nn.softplus(-ft)
    m_new = jnp.maximum(logf + state["m"], it)
    fg = jnp.exp(logf + state["m"] - m_new)
    ig = jnp.exp(it - m_new)
    C = fg[..., None, None] * state["C"] + ig[..., None, None] * \
        (k[..., :, None] * v[..., None, :])
    n = fg[..., None] * state["n"] + ig[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h = (num / den[..., None]).reshape(B, di).astype(x_t.dtype)
    hf = h.astype(jnp.float32)
    h = (hf * jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-6)
         ).astype(h.dtype) * p["norm"]["g"] * jax.nn.silu(z)
    y = h @ p["down"]["w"]
    return y, {"C": C, "n": n, "m": m_new,
               "conv": hist[:, :, 1:].astype(state["conv"].dtype)}


# -- sLSTM ------------------------------------------------------------------


def slstm_init(key, d_model, *, n_heads=4, dtype=jnp.float32):
    hd = d_model // n_heads
    ks = jax.random.split(key, 4)
    return {
        "wx": {"w": cm.mk(ks[0], (d_model, 4 * d_model), ("embed", "mlp"),
                          dtype=dtype)},
        "rec": {"R": cm.mk(ks[1], (4, n_heads, hd, hd), (None, "heads",
                                                         None, None),
                           scale=0.3 / math.sqrt(hd), dtype=jnp.float32),
                "b": cm.mk(ks[2], (4, d_model), (None, "embed"),
                           dist="zeros", dtype=jnp.float32)},
        "norm": {"g": cm.mk(ks[2], (d_model,), ("embed",), dist="ones",
                            dtype=dtype)},
        "ffn": mlp_init(ks[3], d_model, int(d_model * 4 / 3) // 8 * 8,
                        "swiglu", dtype=dtype),
    }


def _slstm_scan(params, gx):
    """gx (B,T,4,D) gate pre-activations from the input side.
    Recurrence: g = gx_t + R h_{t-1} + b, stabilized scalar memory."""
    R, bias = params["R"], params["b"]          # (4,H,hd,hd), (4,D)
    B, T, _, D = gx.shape
    H = R.shape[1]
    hd = D // H

    def step(carry, gx_t):
        c, n, h, m = carry                       # (B,D) each; m (B,D)
        hh = h.reshape(B, H, hd)
        rec = jnp.einsum("ghkv,bhk->gbhv", R, hh).reshape(4, B, D)
        g = gx_t.astype(jnp.float32).transpose(1, 0, 2) + rec \
            + bias[:, None, :]
        i_, f_, z_, o_ = g[0], g[1], g[2], g[3]
        logf = -jax.nn.softplus(-f_)
        m_new = jnp.maximum(logf + m, i_)
        ig = jnp.exp(i_ - m_new)
        fg = jnp.exp(logf + m - m_new)
        c = fg * c + ig * jnp.tanh(z_)
        n = fg * n + ig
        h = jax.nn.sigmoid(o_) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    zeros = jnp.zeros((B, D), jnp.float32)
    init = (zeros, zeros, zeros, zeros)
    _, hs = lax.scan(step, init, jnp.moveaxis(gx, 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(gx.dtype)   # (B,T,D)


def slstm_apply(tp: Tapper, name: str, p, x, *, n_heads=4):
    B, T, D = x.shape
    gx = tp.dense(f"{name}/wx", x, p["wx"]["w"]).reshape(B, T, 4, D)
    h = tp.local_vjp(f"{name}/rec", _slstm_scan, p["rec"], gx)
    h = cm.rmsnorm(tp, f"{name}/norm", p["norm"], h)
    return mlp_apply(tp, f"{name}/ffn", p["ffn"], h, "swiglu")


def slstm_state(batch, d_model, dtype=jnp.float32):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_step(p, state, x_t, *, n_heads=4):
    B, D = x_t.shape
    H = p["rec"]["R"].shape[1]
    hd = D // H
    gx = (x_t @ p["wx"]["w"]).reshape(B, 4, D)
    hh = state["h"].reshape(B, H, hd)
    rec = jnp.einsum("ghkv,bhk->gbhv", p["rec"]["R"], hh).reshape(4, B, D)
    g = gx.astype(jnp.float32).transpose(1, 0, 2) + rec \
        + p["rec"]["b"][:, None, :]
    i_, f_, z_, o_ = g[0], g[1], g[2], g[3]
    logf = -jax.nn.softplus(-f_)
    m_new = jnp.maximum(logf + state["m"], i_)
    ig = jnp.exp(i_ - m_new)
    fg = jnp.exp(logf + state["m"] - m_new)
    c = fg * state["c"] + ig * jnp.tanh(z_)
    n = fg * state["n"] + ig
    h = jax.nn.sigmoid(o_) * c / jnp.maximum(n, 1.0)
    hd_ = h.astype(x_t.dtype)
    nf = hd_.astype(jnp.float32)
    hn = (nf * jax.lax.rsqrt(jnp.mean(nf * nf, -1, keepdims=True) + 1e-6)
          ).astype(x_t.dtype) * p["norm"]["g"]
    # ffn (plain, no taps on the decode path)
    gate = hn @ p["ffn"]["w_gate"]["w"]
    upv = hn @ p["ffn"]["w_up"]["w"]
    y = (jax.nn.silu(gate) * upv) @ p["ffn"]["w_down"]["w"]
    return y, {"c": c, "n": n, "h": h, "m": m_new}
