"""Feed-forward blocks: SwiGLU and GeLU MLPs (tapped)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tapper import Tapper
from repro.launch.sharding import shard_act
from repro.models import common as cm


def mlp_init(key, d_model, d_ff, kind="swiglu", *, bias=False,
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {}
    if kind == "swiglu":
        p["w_gate"] = {"w": cm.mk(ks[0], (d_model, d_ff), ("embed", "mlp"),
                                  dtype=dtype)}
    p["w_up"] = {"w": cm.mk(ks[1], (d_model, d_ff), ("embed", "mlp"),
                            dtype=dtype)}
    p["w_down"] = {"w": cm.mk(ks[2], (d_ff, d_model), ("mlp", "embed"),
                              dtype=dtype)}
    if bias:
        p["w_up"]["b"] = cm.mk(ks[1], (d_ff,), ("mlp",), dist="zeros",
                               dtype=dtype)
        p["w_down"]["b"] = cm.mk(ks[2], (d_model,), ("embed",), dist="zeros",
                                 dtype=dtype)
    return p


def mlp_apply(tp: Tapper, name: str, p, x, kind="swiglu"):
    up = tp.dense(f"{name}/w_up", x, p["w_up"]["w"], p["w_up"].get("b"))
    up = shard_act(up, "batch", "seq", "mlp")
    if kind == "swiglu":
        gate = tp.dense(f"{name}/w_gate", x, p["w_gate"]["w"])
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return tp.dense(f"{name}/w_down", h, p["w_down"]["w"],
                    p["w_down"].get("b"))
