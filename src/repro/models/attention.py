"""Attention variants: GQA (+RoPE, qk-norm, sliding window, cross) and
DeepSeek-style MLA (multi-head latent attention), with KV caches for
prefill/decode serving and a chunked long-context path.

All projections go through tapped denses so per-example gradients cover
every attention parameter.  Serving paths pass a no-op Tapper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.tapper import LayerMeta, Tapper
from repro.launch.sharding import shard_act
from repro.models import common as cm

NEG = -1e30
CHUNK_Q = 1024
AUTO_CHUNK_FROM = 8192


class FlashUnsupportedError(NotImplementedError):
    """``impl="flash"`` was requested for a feature combination the flash
    kernel does not implement (sliding window, cache offsets, valid-length
    masking).  Named so dispatch callers can catch it and fall back."""


# ---------------------------------------------------------------------------
# Core softmax attention


def _sdpa(q, k, v, mask):
    """q (B,T,H,hd), k/v (B,S,H,hd), mask broadcastable to (B,H,T,S)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bthd,bshd->bhts", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", p.astype(v.dtype), v)


def _causal_mask(T, S, offset=0, window=0):
    """mask[t, s] = (s - offset) <= t  [and within window]."""
    t = jnp.arange(T)[:, None]
    s = jnp.arange(S)[None, :] - offset
    m = s <= t
    if window:
        m = m & (s > t - window)
    return m[None, None]


def sdpa_chunked(q, k, v, *, offset=0, window=0, chunk=CHUNK_Q,
                 valid_len=None):
    """Causal attention scanned over query chunks — bounds the (T,S) score
    tensor to (chunk, S).  jnp reference of the flash kernel.

    valid_len masks raw key slots >= valid_len (cache semantics, same as
    the xla path in :func:`attend`)."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    if T % chunk:
        raise ValueError(
            f"sdpa_chunked: query length {T} not divisible by chunk "
            f"{chunk}; pass chunk=min(chunk, T) or pad the sequence")
    n = T // chunk
    qs = jnp.moveaxis(q.reshape(B, n, chunk, H, hd), 1, 0)

    def body(_, qc_i):
        qc, i = qc_i
        t0 = i * chunk
        t = t0 + jnp.arange(chunk)[:, None]
        s = jnp.arange(S)[None, :] - offset
        m = s <= t
        if window:
            m = m & (s > t - window)
        if valid_len is not None:
            sl = jnp.arange(S)[None, :]
            m = m & (sl < valid_len)
            if window:
                m = m & (sl >= valid_len - window)
        return None, _sdpa(qc, k, v, m[None, None])

    _, out = lax.scan(body, None, (qs, jnp.arange(n)))
    return jnp.moveaxis(out, 0, 1).reshape(B, T, H, hd)


def attend(q, k, v, *, causal=True, offset=0, window=0, impl="auto",
           valid_len=None):
    """Dispatch attention impl.  valid_len masks cache slots >= pos."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    if impl == "auto":
        impl = "chunked" if (T >= AUTO_CHUNK_FROM and causal and
                             valid_len is None and T % CHUNK_Q == 0) else "xla"
    if impl == "chunked":
        return sdpa_chunked(q, k, v, offset=offset, window=window,
                            valid_len=valid_len, chunk=min(CHUNK_Q, T))
    if impl == "flash":
        if window or offset or valid_len is not None:
            raise FlashUnsupportedError(
                f"impl='flash' supports plain causal/full attention only "
                f"(got window={window}, offset={offset}, "
                f"valid_len={'set' if valid_len is not None else None}); "
                f"use impl='chunked' or 'xla'")
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal)
    if causal and T > 1:
        mask = _causal_mask(T, S, offset=offset, window=window)
    else:
        mask = jnp.ones((1, 1, T, S), bool)
    if valid_len is not None:
        mask = mask & (jnp.arange(S)[None, None, None, :] < valid_len)
        if window:
            mask = mask & (jnp.arange(S)[None, None, None, :]
                           >= valid_len - window)
    return _sdpa(q, k, v, mask)


def repeat_kv(k, n_rep: int):
    return k if n_rep == 1 else jnp.repeat(k, n_rep, axis=2)


# ---------------------------------------------------------------------------
# GQA attention layer


def gqa_init(key, d_model, n_heads, n_kv, head_dim, *, qk_norm=False,
             bias=False, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    p = {
        "wq": {"w": cm.mk(ks[0], (d_model, n_heads * head_dim),
                          ("embed", "heads"), dtype=dtype)},
        "wk": {"w": cm.mk(ks[1], (d_model, n_kv * head_dim),
                          ("embed", "kv"), dtype=dtype)},
        "wv": {"w": cm.mk(ks[2], (d_model, n_kv * head_dim),
                          ("embed", "kv"), dtype=dtype)},
        "wo": {"w": cm.mk(ks[3], (n_heads * head_dim, d_model),
                          ("heads", "embed"), dtype=dtype)},
    }
    if bias:
        for i, n in enumerate(("wq", "wk", "wv", "wo")):
            dim = p[n]["w"].value.shape[1]
            ax = p[n]["w"].axes[1]
            p[n]["b"] = cm.mk(ks[4 + i], (dim,), (ax,), dist="zeros",
                              dtype=dtype)
    if qk_norm:
        p["qn"] = {"g": cm.mk(ks[4], (head_dim,), (None,), dist="ones",
                              dtype=dtype)}
        p["kn"] = {"g": cm.mk(ks[5], (head_dim,), (None,), dist="ones",
                              dtype=dtype)}
    return p


def gqa_apply(tp: Tapper, name: str, p, x, *, n_heads, n_kv, head_dim,
              rope_theta=1e4, qk_norm=False, positions=None, causal=True,
              window=0, cache=None, x_kv=None, attn_impl="auto",
              use_rope=True, dp_attn=False):
    """Returns (attn_out, new_cache).  cache: {"k","v","pos"} or None.

    x_kv: source sequence for cross attention (no cache, no causal mask,
    no rope on either side unless positions given).

    dp_attn: tap the whole block as one ``"attn"`` layer (see kinds.py) —
    per-example norms for wq/wk/wv/wo come from a layer-local recompute
    instead of per-projection captures, so the planner can price the
    block's ghost norm as a unit.  Falls back to per-projection taps for
    serving (cache), cross-attention, windowed, shared ("~") and
    explicit-positions call sites.
    """
    if (dp_attn and tp.active() and cache is None and x_kv is None
            and not window and positions is None
            and not name.startswith("~")):
        kw = dict(n_heads=n_heads, n_kv=n_kv, head_dim=head_dim,
                  rope_theta=rope_theta, qk_norm=qk_norm, causal=causal,
                  attn_impl=attn_impl, use_rope=use_rope)

        def rebuild(inner_tp, psub, xin):
            y, _ = gqa_apply(inner_tp, "blk", psub, xin, **kw)
            return y

        y = rebuild(Tapper(), p, x)
        D = x.shape[-1]
        meta = LayerMeta(
            "attn", tuple(name.split("/")),
            static={"proj_dims": ((D, n_heads * head_dim),
                                  (D, n_kv * head_dim),
                                  (D, n_kv * head_dim),
                                  (n_heads * head_dim, D)),
                    "qk_flops": n_heads * head_dim},
            fn=rebuild)
        return tp.tap(name, y, {"x": x}, meta), None

    B, T, _ = x.shape
    q = tp.dense(f"{name}/wq", x, p["wq"]["w"], p["wq"].get("b"))
    src = x if x_kv is None else x_kv
    k = tp.dense(f"{name}/wk", src, p["wk"]["w"], p["wk"].get("b"))
    v = tp.dense(f"{name}/wv", src, p["wv"]["w"], p["wv"].get("b"))
    S = src.shape[1]
    q = q.reshape(B, T, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    q = shard_act(q, "batch", "seq", "heads", None)
    k = shard_act(k, "batch", "seq", "kv", None)

    if qk_norm:
        q = cm.rmsnorm(tp, f"{name}/qn", p["qn"], q)
        k = cm.rmsnorm(tp, f"{name}/kn", p["kn"], k)

    if use_rope and x_kv is None:
        if positions is None:
            positions = jnp.arange(T)[None, :] + (
                cache["pos"] if cache is not None else 0)
            positions = jnp.broadcast_to(positions, (B, T))
        cos, sin = cm.rope_angles(positions, head_dim, rope_theta)
        q = cm.apply_rope(q, cos, sin)
        kpos = positions if cache is None else positions
        cos_k, sin_k = cm.rope_angles(kpos, head_dim, rope_theta)
        k = cm.apply_rope(k, cos_k, sin_k)

    new_cache = None
    if cache is not None:
        S_max = cache["k"].shape[1]
        ring = bool(window) and S_max <= window  # fixed-size rolling cache
        idx = lax.rem(cache["pos"], S_max) if ring else cache["pos"]
        ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, idx, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": cache["pos"] + T}
        k, v = ck, cv
        valid = jnp.minimum(new_cache["pos"], S_max)
        out = attend(q, repeat_kv(k, n_heads // n_kv),
                     repeat_kv(v, n_heads // n_kv),
                     causal=(T > 1), offset=idx, valid_len=valid, window=0,
                     impl="xla")
    else:
        out = attend(q, repeat_kv(k, n_heads // n_kv),
                     repeat_kv(v, n_heads // n_kv),
                     causal=causal and x_kv is None, window=window,
                     impl=attn_impl)

    out = out.reshape(B, T, n_heads * head_dim)
    out = tp.dense(f"{name}/wo", out, p["wo"]["w"], p["wo"].get("b"))
    return out, new_cache


def gqa_cache(batch, max_len, n_kv, head_dim, dtype=jnp.float32):
    return {"k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            "pos": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): latent-compressed KV, decoupled rope head


def mla_init(key, d_model, n_heads, *, q_lora_rank, kv_lora_rank, qk_nope_dim,
             qk_rope_dim, v_head_dim, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    qd = qk_nope_dim + qk_rope_dim
    p = {
        "wkv_a": {"w": cm.mk(ks[2], (d_model, kv_lora_rank + qk_rope_dim),
                             ("embed", "kvrank"), dtype=dtype)},
        "kv_norm": {"g": cm.mk(ks[3], (kv_lora_rank,), ("kvrank",),
                               dist="ones", dtype=dtype)},
        "wkv_b": {"w": cm.mk(ks[4], (kv_lora_rank,
                                     n_heads * (qk_nope_dim + v_head_dim)),
                             ("kvrank", "heads"), dtype=dtype)},
        "wo": {"w": cm.mk(ks[5], (n_heads * v_head_dim, d_model),
                          ("heads", "embed"), dtype=dtype)},
    }
    if q_lora_rank:
        p["wq_a"] = {"w": cm.mk(ks[0], (d_model, q_lora_rank),
                                ("embed", "qrank"), dtype=dtype)}
        p["q_norm"] = {"g": cm.mk(ks[6], (q_lora_rank,), ("qrank",),
                                  dist="ones", dtype=dtype)}
        p["wq_b"] = {"w": cm.mk(ks[1], (q_lora_rank, n_heads * qd),
                                ("qrank", "heads"), dtype=dtype)}
    else:
        p["wq"] = {"w": cm.mk(ks[0], (d_model, n_heads * qd),
                              ("embed", "heads"), dtype=dtype)}
    return p


def mla_apply(tp: Tapper, name: str, p, x, *, n_heads, q_lora_rank,
              kv_lora_rank, qk_nope_dim, qk_rope_dim, v_head_dim,
              rope_theta=1e4, positions=None, cache=None, attn_impl="auto",
              absorbed_decode: bool = False, dp_attn=False):
    """Returns (out, new_cache).  cache stores the *latent* kv:
    {"ckv" (B,S,kvr), "krope" (B,S,dr), "pos"}.

    dp_attn: block-level "attn" tap (see gqa_apply) over the train path.
    """
    B, T, D = x.shape
    qd = qk_nope_dim + qk_rope_dim

    if (dp_attn and tp.active() and cache is None and positions is None
            and not name.startswith("~")):
        kw = dict(n_heads=n_heads, q_lora_rank=q_lora_rank,
                  kv_lora_rank=kv_lora_rank, qk_nope_dim=qk_nope_dim,
                  qk_rope_dim=qk_rope_dim, v_head_dim=v_head_dim,
                  rope_theta=rope_theta, attn_impl=attn_impl)

        def rebuild(inner_tp, psub, xin):
            y, _ = mla_apply(inner_tp, "blk", psub, xin, **kw)
            return y

        y = rebuild(Tapper(), p, x)
        q_dims = (((D, q_lora_rank), (q_lora_rank, n_heads * qd))
                  if q_lora_rank else ((D, n_heads * qd),))
        meta = LayerMeta(
            "attn", tuple(name.split("/")),
            static={"proj_dims": q_dims + (
                        (D, kv_lora_rank + qk_rope_dim),
                        (kv_lora_rank, n_heads * (qk_nope_dim + v_head_dim)),
                        (n_heads * v_head_dim, D)),
                    "qk_flops": n_heads * qd},
            fn=rebuild)
        return tp.tap(name, y, {"x": x}, meta), None

    if q_lora_rank:
        cq = tp.dense(f"{name}/wq_a", x, p["wq_a"]["w"])
        cq = cm.rmsnorm(tp, f"{name}/q_norm", p["q_norm"], cq)
        q = tp.dense(f"{name}/wq_b", cq, p["wq_b"]["w"])
    else:
        q = tp.dense(f"{name}/wq", x, p["wq"]["w"])
    q = q.reshape(B, T, n_heads, qd)
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]

    kv_a = tp.dense(f"{name}/wkv_a", x, p["wkv_a"]["w"])
    ckv, k_rope = kv_a[..., :kv_lora_rank], kv_a[..., kv_lora_rank:]
    ckv = cm.rmsnorm(tp, f"{name}/kv_norm", p["kv_norm"], ckv)

    if positions is None:
        positions = jnp.arange(T)[None, :] + (
            cache["pos"] if cache is not None else 0)
        positions = jnp.broadcast_to(positions, (B, T))
    cos, sin = cm.rope_angles(positions, qk_rope_dim, rope_theta)
    q_rope = cm.apply_rope(q_rope, cos, sin)
    k_rope = cm.apply_rope(k_rope[:, :, None, :], cos, sin)  # (B,T,1,dr)

    new_cache = None
    if cache is not None:
        ckv_c = lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache["pos"], 0))
        kr_c = lax.dynamic_update_slice(
            cache["krope"], k_rope[:, :, 0].astype(cache["krope"].dtype),
            (0, cache["pos"], 0))
        new_cache = {"ckv": ckv_c, "krope": kr_c, "pos": cache["pos"] + T}
        S = ckv_c.shape[1]
        valid = new_cache["pos"]
        if absorbed_decode:
            # Fold wkv_b into the query/output sides: attention runs in the
            # latent space, no per-step decompression of the whole cache.
            wkv_b = p["wkv_b"]["w"].reshape(
                kv_lora_rank, n_heads, qk_nope_dim + v_head_dim)
            wk_b, wv_b = wkv_b[..., :qk_nope_dim], wkv_b[..., qk_nope_dim:]
            q_lat = jnp.einsum("bthd,chd->bthc", q_nope, wk_b)
            scale = qd ** -0.5
            s = (jnp.einsum("bthc,bsc->bhts", q_lat, ckv_c,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bthr,bsr->bhts", q_rope, kr_c,
                              preferred_element_type=jnp.float32)) * scale
            mask = jnp.arange(S)[None, None, None, :] < valid
            if T > 1:  # causal among the new tokens (prefill-into-cache)
                t_idx = cache["pos"] + jnp.arange(T)[:, None]
                mask = mask & (jnp.arange(S)[None, :] <= t_idx)[None, None]
            s = jnp.where(mask, s, NEG)
            pr = jax.nn.softmax(s, axis=-1).astype(ckv_c.dtype)
            o_lat = jnp.einsum("bhts,bsc->bthc", pr, ckv_c)
            out = jnp.einsum("bthc,chd->bthd", o_lat, wv_b)
        else:
            kv = jnp.matmul(ckv_c, p["wkv_b"]["w"]).reshape(
                B, S, n_heads, qk_nope_dim + v_head_dim)
            k_nope, vfull = kv[..., :qk_nope_dim], kv[..., qk_nope_dim:]
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr_c[:, :, None, :],
                                          (B, S, n_heads, qk_rope_dim))], -1)
            qf = jnp.concatenate([q_nope, q_rope], -1)
            out = attend(qf, k_full, vfull, causal=(T > 1),
                         offset=cache["pos"], valid_len=valid, impl="xla")
        out = out.reshape(B, T, n_heads * v_head_dim)
        out = tp.dense(f"{name}/wo", out, p["wo"]["w"])
        return out, new_cache

    # train / prefill-style full pass
    kv = tp.dense(f"{name}/wkv_b", ckv, p["wkv_b"]["w"]).reshape(
        B, T, n_heads, qk_nope_dim + v_head_dim)
    k_nope, v = kv[..., :qk_nope_dim], kv[..., qk_nope_dim:]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, T, n_heads, qk_rope_dim))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    out = attend(qf, k_full, v, causal=True, impl=attn_impl)
    out = out.reshape(B, T, n_heads * v_head_dim)
    out = tp.dense(f"{name}/wo", out, p["wo"]["w"])
    return out, None


def mla_cache(batch, max_len, kv_lora_rank, qk_rope_dim, dtype=jnp.float32):
    return {"ckv": jnp.zeros((batch, max_len, kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, qk_rope_dim), dtype),
            "pos": jnp.zeros((), jnp.int32)}
