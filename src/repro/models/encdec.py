"""Encoder-decoder LM (Seamless-M4T v2 backbone).

The speech/multimodal frontend is a stub per the assignment: ``input_specs``
provides precomputed frame embeddings (B, T_src, d_model); the transformer
backbone (bidirectional encoder + causal decoder with cross attention) is
real and fully tap-covered for per-example gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.tapper import Tapper, scan_with_taps
from repro.launch.sharding import shard_act
from repro.models import attention as attn
from repro.models import common as cm
from repro.models.mlp import mlp_apply, mlp_init


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ----------------------------------------------------------
    def _enc_block(self, key):
        c = self.cfg
        ks = jax.random.split(key, 4)
        return {"attn": attn.gqa_init(ks[0], c.d_model, c.n_heads, c.n_kv,
                                      c.hd, dtype=c.jdtype),
                "mlp": mlp_init(ks[1], c.d_model, c.d_ff, c.mlp,
                                dtype=c.jdtype),
                "ln1": cm.norm_init(ks[2], c.d_model, c.norm, c.jdtype),
                "ln2": cm.norm_init(ks[3], c.d_model, c.norm, c.jdtype)}

    def _dec_block(self, key):
        c = self.cfg
        ks = jax.random.split(key, 6)
        return {"self": attn.gqa_init(ks[0], c.d_model, c.n_heads, c.n_kv,
                                      c.hd, dtype=c.jdtype),
                "cross": attn.gqa_init(ks[1], c.d_model, c.n_heads, c.n_kv,
                                       c.hd, dtype=c.jdtype),
                "mlp": mlp_init(ks[2], c.d_model, c.d_ff, c.mlp,
                                dtype=c.jdtype),
                "ln1": cm.norm_init(ks[3], c.d_model, c.norm, c.jdtype),
                "ln2": cm.norm_init(ks[4], c.d_model, c.norm, c.jdtype),
                "ln3": cm.norm_init(ks[5], c.d_model, c.norm, c.jdtype)}

    def init(self, key):
        c = self.cfg
        ks = jax.random.split(key, 5)
        tree = {
            "tok_emb": {"emb": cm.mk(ks[0], (c.padded_vocab, c.d_model),
                                     ("vocab", "embed"), scale=0.02,
                                     dtype=c.jdtype)},
            "enc": cm.stack_layers(ks[1], c.n_enc_layers, self._enc_block),
            "dec": cm.stack_layers(ks[2], c.n_dec_layers, self._dec_block),
            "final_norm": cm.norm_init(ks[3], c.d_model, c.norm, c.jdtype),
            "head": {"w": cm.mk(ks[4], (c.d_model, c.padded_vocab),
                                ("embed", "vocab"), scale=0.02,
                                dtype=c.jdtype)},
        }
        if tree["final_norm"] is None:
            tree.pop("final_norm")
        return cm.split_tree(tree)

    def _attn_kw(self):
        c = self.cfg
        return dict(n_heads=c.n_heads, n_kv=c.n_kv, head_dim=c.hd,
                    rope_theta=c.rope_theta, attn_impl=c.attn_impl)

    # -- encode ----------------------------------------------------------
    def encode(self, params, src, tp: Tapper):
        c = self.cfg

        def body(stp, h, p_l, _):
            z = cm.apply_norm(stp, "ln1", p_l.get("ln1"), h, c.norm)
            a, _ = attn.gqa_apply(stp, "attn", p_l["attn"], z, causal=False,
                                  **self._attn_kw())
            h = h + a
            z = cm.apply_norm(stp, "ln2", p_l.get("ln2"), h, c.norm)
            return h + mlp_apply(stp, "mlp", p_l["mlp"], z, c.mlp)

        return scan_with_taps(tp, "enc", body, src, params["enc"])

    # -- train -----------------------------------------------------------
    def apply(self, params, batch, tp: Tapper):
        c = self.cfg
        src = batch["src_frames"].astype(c.jdtype)
        tokens, labels = batch["tokens"], batch["labels"]
        enc_out = self.encode(params, src, tp)
        h = tp.embed("tok_emb", params["tok_emb"]["emb"], tokens)

        def body(stp, hh, p_l, _):
            z = cm.apply_norm(stp, "ln1", p_l.get("ln1"), hh, c.norm)
            a, _ = attn.gqa_apply(stp, "self", p_l["self"], z, causal=True,
                                  **self._attn_kw())
            hh = hh + a
            z = cm.apply_norm(stp, "ln2", p_l.get("ln2"), hh, c.norm)
            a, _ = attn.gqa_apply(stp, "cross", p_l["cross"], z,
                                  x_kv=enc_out, **self._attn_kw())
            hh = hh + a
            z = cm.apply_norm(stp, "ln3", p_l.get("ln3"), hh, c.norm)
            return hh + mlp_apply(stp, "mlp", p_l["mlp"], z, c.mlp)

        h = scan_with_taps(tp, "dec", body, h, params["dec"], remat=c.remat)
        h = cm.apply_norm(tp, "final_norm", params.get("final_norm"), h,
                          c.norm)
        logits = tp.dense("head", h, params["head"]["w"])
        return cm.per_example_xent(logits, labels, batch.get("mask"),
                                   vocab_valid=c.vocab)

    # -- serve -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, src_len: int):
        c = self.cfg
        one = attn.gqa_cache(batch, max_len, c.n_kv, c.hd, c.jdtype)
        one.pop("pos")
        L = c.n_dec_layers
        return {
            "self": jax.tree.map(lambda a: jnp.zeros((L,) + a.shape,
                                                     a.dtype), one),
            "cross_k": jnp.zeros((L, batch, src_len, c.n_kv, c.hd), c.jdtype),
            "cross_v": jnp.zeros((L, batch, src_len, c.n_kv, c.hd), c.jdtype),
            "pos": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, src, tokens, max_len: int):
        """Encode + teacher-forced decoder prefill."""
        c = self.cfg
        tp = Tapper()
        B, T = tokens.shape
        src = src.astype(c.jdtype)
        enc_out = self.encode(params, src, tp)
        cache = self.init_cache(B, max_len, src.shape[1])

        # per-layer cross kv (computed once)
        def cross_kv(carry, p_l):
            k = jnp.matmul(enc_out, p_l["cross"]["wk"]["w"])
            v = jnp.matmul(enc_out, p_l["cross"]["wv"]["w"])
            S = enc_out.shape[1]
            return carry, (k.reshape(B, S, c.n_kv, c.hd),
                           v.reshape(B, S, c.n_kv, c.hd))

        _, (ck, cv) = lax.scan(cross_kv, None, params["dec"])
        cache["cross_k"], cache["cross_v"] = ck, cv

        h = params["tok_emb"]["emb"][tokens]

        def body(hh, xs):
            p_l, c_l, k_l, v_l = xs
            cl = dict(c_l)
            cl["pos"] = jnp.zeros((), jnp.int32)
            z = cm.apply_norm(tp, "ln1", p_l.get("ln1"), hh, c.norm)
            a, nc = attn.gqa_apply(tp, "self", p_l["self"], z, cache=cl,
                                   **self._attn_kw())
            hh = hh + a
            z = cm.apply_norm(tp, "ln2", p_l.get("ln2"), hh, c.norm)
            hh = hh + self._cross_decode(p_l, z, k_l, v_l)
            z = cm.apply_norm(tp, "ln3", p_l.get("ln3"), hh, c.norm)
            hh = hh + mlp_apply(tp, "mlp", p_l["mlp"], z, c.mlp)
            nc.pop("pos")
            return hh, nc

        h, new_self = lax.scan(body, h, (params["dec"], cache["self"],
                                         ck, cv))
        if c.prefill_last_only:
            h = h[:, -1:]
        h = cm.apply_norm(tp, "fn", params.get("final_norm"), h, c.norm)
        logits = jnp.matmul(h[:, -1], params["head"]["w"])
        cache["self"] = new_self
        cache["pos"] = jnp.full((), T, jnp.int32)
        return logits, cache

    def _cross_decode(self, p_l, z, k_l, v_l):
        c = self.cfg
        B, T, _ = z.shape
        q = jnp.matmul(z, p_l["cross"]["wq"]["w"]).reshape(B, T, c.n_heads,
                                                           c.hd)
        out = attn.attend(q, attn.repeat_kv(k_l, c.n_heads // c.n_kv),
                          attn.repeat_kv(v_l, c.n_heads // c.n_kv),
                          causal=False, impl="xla")
        out = out.reshape(B, T, c.n_heads * c.hd)
        return jnp.matmul(out, p_l["cross"]["wo"]["w"])

    def decode_step(self, params, cache, tokens):
        c = self.cfg
        tp = Tapper()
        h = params["tok_emb"]["emb"][tokens][:, None, :]
        pos = cache["pos"]

        def body(hh, xs):
            p_l, c_l, k_l, v_l = xs
            cl = dict(c_l)
            cl["pos"] = pos
            z = cm.apply_norm(tp, "ln1", p_l.get("ln1"), hh, c.norm)
            a, nc = attn.gqa_apply(tp, "self", p_l["self"], z, cache=cl,
                                   **self._attn_kw())
            hh = hh + a
            z = cm.apply_norm(tp, "ln2", p_l.get("ln2"), hh, c.norm)
            hh = hh + self._cross_decode(p_l, z, k_l, v_l)
            z = cm.apply_norm(tp, "ln3", p_l.get("ln3"), hh, c.norm)
            hh = hh + mlp_apply(tp, "mlp", p_l["mlp"], z, c.mlp)
            nc.pop("pos")
            return hh, nc

        h, new_self = lax.scan(body, h, (params["dec"], cache["self"],
                                         cache["cross_k"], cache["cross_v"]))
        h = cm.apply_norm(tp, "fn", params.get("final_norm"), h, c.norm)
        logits = jnp.matmul(h[:, 0], params["head"]["w"])
        new_cache = dict(cache)
        new_cache["self"] = new_self
        new_cache["pos"] = pos + 1
        return logits, new_cache

    # -- specs -----------------------------------------------------------
    def train_input_specs(self, shape: ShapeSpec):
        c = self.cfg
        B, T = shape.global_batch, shape.seq_len
        Ts, Tt = T // 2, T // 2
        return {"src_frames": jax.ShapeDtypeStruct((B, Ts, c.d_model),
                                                   jnp.float32),
                "tokens": jax.ShapeDtypeStruct((B, Tt), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, Tt), jnp.int32)}

    def prefill_input_specs(self, shape: ShapeSpec):
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        return {"src_frames": jax.ShapeDtypeStruct((B, S // 2, c.d_model),
                                                   jnp.float32),
                "tokens": jax.ShapeDtypeStruct((B, S // 2), jnp.int32)}

    def decode_input_specs(self, shape: ShapeSpec):
        B, S = shape.global_batch, shape.seq_len
        cache = jax.eval_shape(
            lambda: self.init_cache(B, S // 2, S // 2))
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
