"""Mixture-of-experts with tapped expert matmuls.

Two dispatch implementations:

  * ``einsum`` — GSPMD-style dense dispatch/combine one-hot einsums with
    *per-example* capacity (DP-pure: examples never compete for slots).
    This is the compile-anywhere baseline; its dispatch FLOPs are the
    classic quadratic-in-tokens overhead visible in the roofline.
  * ``gather`` — sort-free scatter/gather dispatch with global capacity:
    sub-quadratic, the §Perf replacement.  Slot competition is only a DP
    concern when capacity is tight; we provision ample capacity.

Expert FFN matmuls are registered through ``Tapper.dense_segmented`` so
per-example gradient norms for expert weights are exact (slot→example ids
travel with the captures).

The router is a plain tapped dense; the load-balance auxiliary loss is
computed *per example* (over that example's own tokens) to preserve
per-example loss semantics under DP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tapper import Tapper
from repro.launch.sharding import shard_act
from repro.models import common as cm
from repro.models.mlp import mlp_apply, mlp_init


def moe_init(key, d_model, d_ff, n_experts, *, n_shared=0, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": cm.mk(ks[0], (d_model, n_experts), ("embed", "expert"),
                              dtype=dtype)},
        "w_gate": {"w": cm.mk(ks[1], (n_experts, d_model, d_ff),
                              ("expert", "embed", "mlp"), dtype=dtype)},
        "w_up": {"w": cm.mk(ks[2], (n_experts, d_model, d_ff),
                            ("expert", "embed", "mlp"), dtype=dtype)},
        "w_down": {"w": cm.mk(ks[3], (n_experts, d_ff, d_model),
                              ("expert", "mlp", "embed"), dtype=dtype)},
    }
    if n_shared:
        p["shared"] = mlp_init(ks[4], d_model, d_ff * n_shared, "swiglu",
                               dtype=dtype)
    return p


def _router(tp, name, p, x, n_experts, topk):
    logits = tp.dense(f"{name}/router", x, p["router"]["w"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, topk)           # (B,T,k)
    top_w = top_w / jnp.sum(top_w, -1, keepdims=True)
    # per-example load-balance loss (Switch-style), DP-pure
    imp = jnp.mean(probs, axis=1)                        # (B,E)
    frac = jnp.mean(
        jax.nn.one_hot(top_e, n_experts, dtype=jnp.float32), axis=(1, 2))
    lb = n_experts * jnp.sum(imp * frac, axis=-1)        # (B,)
    return probs, top_w, top_e, lb


def moe_apply_einsum(tp: Tapper, name: str, p, x, *, n_experts, topk,
                     capacity_factor=2.0, d_ff=None):
    """Per-example-capacity dense dispatch (DP-pure)."""
    B, T, D = x.shape
    E = n_experts
    cap = max(1, int(capacity_factor * T * topk / E))
    probs, top_w, top_e, lb = _router(tp, name, p, x, E, topk)

    onehot = jax.nn.one_hot(top_e, E, dtype=x.dtype)     # (B,T,k,E)
    # position of token t among tokens of *its own example* routed to e
    pos = jnp.cumsum(onehot.reshape(B, T * topk, E), axis=1) - 1
    pos = pos.reshape(B, T, topk, E)
    keep = (pos < cap).astype(x.dtype) * onehot
    posc = jax.nn.one_hot(pos, cap, dtype=x.dtype)       # (B,T,k,E,C)
    disp = jnp.einsum("btke,btkec->btec", keep, posc)
    comb = jnp.einsum("btk,btke,btkec->btec", top_w.astype(x.dtype), keep, posc)

    xe = jnp.einsum("btd,btec->ebcd", x, disp)           # (E,B,C,D)
    xe = xe.reshape(E, B * cap, D)
    xe = shard_act(xe, "expert", None, None)
    seg = jnp.broadcast_to(jnp.arange(B)[None, :, None], (E, B, cap))
    seg = seg.reshape(E, B * cap)

    h_g = tp.dense_segmented(f"{name}/w_gate", xe, p["w_gate"]["w"], seg,
                             n_examples=B)
    h_u = tp.dense_segmented(f"{name}/w_up", xe, p["w_up"]["w"], seg,
                             n_examples=B)
    h = jax.nn.silu(h_g) * h_u
    ye = tp.dense_segmented(f"{name}/w_down", h, p["w_down"]["w"], seg,
                            n_examples=B)
    ye = ye.reshape(E, B, cap, D)
    y = jnp.einsum("ebcd,btec->btd", ye, comb)

    if "shared" in p:
        y = y + mlp_apply(tp, f"{name}/shared", p["shared"], x, "swiglu")
    return y, lb


def moe_apply_gather(tp: Tapper, name: str, p, x, *, n_experts, topk,
                     capacity_factor=2.0, d_ff=None):
    """Scatter/gather dispatch with global capacity — sub-quadratic."""
    B, T, D = x.shape
    E = n_experts
    N = B * T
    cap = max(1, int(capacity_factor * N * topk / E))
    probs, top_w, top_e, lb = _router(tp, name, p, x, E, topk)

    e_flat = top_e.reshape(N * topk)                         # (N*k,)
    w_flat = top_w.reshape(N * topk).astype(x.dtype)
    tok_of = jnp.repeat(jnp.arange(N), topk)                 # (N*k,)
    ex_of = tok_of // T                                      # example ids
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)      # (N*k,E)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = pos < cap
    pos = jnp.where(keep, pos, cap - 1)

    xf = x.reshape(N, D)
    xe = jnp.zeros((E, cap, D), x.dtype)
    xe = xe.at[e_flat, pos].add(
        jnp.where(keep[:, None], xf[tok_of], 0).astype(x.dtype))
    seg = jnp.zeros((E, cap), jnp.int32)
    seg = seg.at[e_flat, pos].max(
        jnp.where(keep, ex_of, 0).astype(jnp.int32))

    h_g = tp.dense_segmented(f"{name}/w_gate", xe, p["w_gate"]["w"], seg,
                             n_examples=B)
    h_u = tp.dense_segmented(f"{name}/w_up", xe, p["w_up"]["w"], seg,
                             n_examples=B)
    h = jax.nn.silu(h_g) * h_u
    ye = tp.dense_segmented(f"{name}/w_down", h, p["w_down"]["w"], seg,
                            n_examples=B)

    yt = ye[e_flat, pos] * jnp.where(keep, w_flat, 0)[:, None]  # (N*k, D)
    y = jax.ops.segment_sum(yt, tok_of, num_segments=N).astype(x.dtype)
    y = y.reshape(B, T, D)
    if "shared" in p:
        y = y + mlp_apply(tp, f"{name}/shared", p["shared"], x, "swiglu")
    return y, lb


def moe_apply_sort(tp: Tapper, name: str, p, x, *, n_experts, topk,
                   capacity_factor=2.0, d_ff=None):
    """Sort-based dispatch: positions within experts come from one stable
    argsort + searchsorted instead of the (N·k, E) one-hot cumsum — the
    integer bookkeeping drops from O(N·k·E) to O(N·k·log) bytes (§Perf)."""
    B, T, D = x.shape
    E = n_experts
    N = B * T
    cap = max(1, int(capacity_factor * N * topk / E))
    probs, top_w, top_e, lb = _router(tp, name, p, x, E, topk)

    e_flat = top_e.reshape(N * topk)
    w_flat = top_w.reshape(N * topk).astype(x.dtype)
    tok_of = jnp.repeat(jnp.arange(N), topk)
    ex_of = tok_of // T

    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    start = jnp.searchsorted(e_sorted, jnp.arange(E))
    pos_sorted = jnp.arange(N * topk) - start[e_sorted]
    keep_s = pos_sorted < cap
    pos_s = jnp.where(keep_s, pos_sorted, cap - 1)

    xf = x.reshape(N, D)
    xe = jnp.zeros((E, cap, D), x.dtype)
    xe = xe.at[e_sorted, pos_s].add(
        jnp.where(keep_s[:, None], xf[tok_of[order]], 0).astype(x.dtype))
    seg = jnp.zeros((E, cap), jnp.int32)
    seg = seg.at[e_sorted, pos_s].max(
        jnp.where(keep_s, ex_of[order], 0).astype(jnp.int32))

    h_g = tp.dense_segmented(f"{name}/w_gate", xe, p["w_gate"]["w"], seg,
                             n_examples=B)
    h_u = tp.dense_segmented(f"{name}/w_up", xe, p["w_up"]["w"], seg,
                             n_examples=B)
    h = jax.nn.silu(h_g) * h_u
    ye = tp.dense_segmented(f"{name}/w_down", h, p["w_down"]["w"], seg,
                            n_examples=B)

    yt = ye[e_sorted, pos_s] * jnp.where(keep_s, w_flat[order], 0)[:, None]
    y = jax.ops.segment_sum(yt, tok_of[order], num_segments=N).astype(x.dtype)
    y = y.reshape(B, T, D)
    if "shared" in p:
        y = y + mlp_apply(tp, f"{name}/shared", p["shared"], x, "swiglu")
    return y, lb


def moe_apply(tp, name, p, x, *, impl="einsum", **kw):
    if impl == "gather":
        return moe_apply_gather(tp, name, p, x, **kw)
    if impl == "sort":
        return moe_apply_sort(tp, name, p, x, **kw)
    return moe_apply_einsum(tp, name, p, x, **kw)
