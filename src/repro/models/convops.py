"""Convolution forward + the paper's per-example conv-gradient trick.

Layout is NC(spatial) for inputs, (D, C/groups, *K) for weights — the
paper's (PyTorch) convention.  Works for 1-D/2-D/3-D convolutions.

``pe_conv_grad`` implements Algorithm 2 of Rochette et al. (2019) on XLA:

  * ``impl="fgc"`` — the paper-faithful lowering: the per-example
    convolution ``x ⊛ δy`` is expressed as a grouped convolution with
    ``feature_group_count = B·Γ``, one *extra* spatial dimension holding
    the layer's input channels, ``stride`` and ``dilation`` swapped, and
    the output truncated to the kernel size.
  * ``impl="bgc"`` — the XLA-native variant using ``batch_group_count``
    (the mechanism XLA itself uses for conv weight gradients); no input
    reshape of the batch into channels is required.  XLA allows only one
    group count > 1, so layer groups Γ fold into the batch groups.
  * ``impl="pallas"`` — the TPU kernel in :mod:`repro.kernels.pe_conv_grad`
    (used on TPU; falls back to interpret mode elsewhere).

All three are validated against the brute-force oracle in
``kernels/ref.py`` and against autodiff (summed over the batch).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax


def _tup(v, rank: int):
    if isinstance(v, (tuple, list)):
        assert len(v) == rank, (v, rank)
        return tuple(int(x) for x in v)
    return (int(v),) * rank


def _dn(rank: int) -> lax.ConvDimensionNumbers:
    """NC(spatial) everywhere, as explicit index tuples (any rank)."""
    spec = (0, 1) + tuple(range(2, 2 + rank))
    return lax.ConvDimensionNumbers(spec, spec, spec)


def conv_forward(x, w, *, stride=1, dilation=1, padding=0, groups: int = 1):
    """y[b,d,t] = Σ_{c,k} x[b, c, s·t + r·k] · w[d,c,k]  (+ groups)."""
    rank = x.ndim - 2
    s, r, p = _tup(stride, rank), _tup(dilation, rank), _tup(padding, rank)
    return lax.conv_general_dilated(
        x, w, window_strides=s, padding=tuple((pi, pi) for pi in p),
        rhs_dilation=r, dimension_numbers=_dn(rank),
        feature_group_count=groups)


def unfold_patches(x, kernel_spatial, *, stride=1, dilation=1, padding=0):
    """im2col: x (B, C, *S) -> (B, C·K, T) patch matrix, K = prod(kernel),
    T = prod(out_spatial).  Channel ordering is input-channel major /
    filter-position minor, so per-group feature blocks stay contiguous."""
    rank = len(kernel_spatial)
    s, r, p = _tup(stride, rank), _tup(dilation, rank), _tup(padding, rank)
    patches = lax.conv_general_dilated_patches(
        x, filter_shape=tuple(int(k) for k in kernel_spatial),
        window_strides=s, padding=tuple((pi, pi) for pi in p),
        rhs_dilation=r)
    return patches.reshape(x.shape[0], patches.shape[1], -1)


def conv_output_spatial(in_spatial, kernel_spatial, stride, dilation, padding):
    rank = len(kernel_spatial)
    s, r, p = _tup(stride, rank), _tup(dilation, rank), _tup(padding, rank)
    return tuple(
        (t + 2 * pi - ri * (k - 1) - 1) // si + 1
        for t, k, si, ri, pi in zip(in_spatial, kernel_spatial, s, r, p))


def pe_conv_grad(x, dy, *, kernel_spatial, stride=1, dilation=1, padding=0,
                 groups: int = 1, impl: str = "fgc"):
    """Per-example convolution-weight gradients (Algorithm 2).

    x: (B, C, *S); dy: (B, D, *S').  Returns (B, D, C/Γ, *K).
    """
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.pe_conv_grad(x, dy, kernel_spatial=kernel_spatial,
                                 stride=stride, dilation=dilation,
                                 padding=padding, groups=groups)
    rank = len(kernel_spatial)
    B, C = x.shape[:2]
    D = dy.shape[1]
    s, r, p = _tup(stride, rank), _tup(dilation, rank), _tup(padding, rank)
    g = groups

    if impl == "fgc":
        lhs = x.reshape((1, B * g, C // g) + x.shape[2:])
        fgc, bgc = B * g, 1
    elif impl == "bgc":
        lhs = x.reshape((B * g, 1, C // g) + x.shape[2:])
        fgc, bgc = 1, B * g
    else:
        raise ValueError(f"unknown impl {impl!r}")

    rhs = dy.reshape((B * D, 1, 1) + dy.shape[2:])
    out = lax.conv_general_dilated(
        lhs, rhs,
        window_strides=(1,) + r,                 # stride <- dilation
        padding=((0, 0),) + tuple((pi, pi) for pi in p),
        rhs_dilation=(1,) + s,                   # dilation <- stride
        dimension_numbers=_dn(rank + 1),
        feature_group_count=fgc, batch_group_count=bgc)
    # out: (1, B*D, C/Γ, *K⁺) — truncate the floor-induced extra taps.
    out = out[0]
    out = out[(slice(None), slice(None))
              + tuple(slice(0, k) for k in kernel_spatial)]
    return out.reshape((B, D, C // g) + tuple(kernel_spatial))
