"""Decoder-only language models for all assigned LM families.

One class covers dense / moe / vlm (early-fusion backbone) / ssm (xLSTM) /
hybrid (Zamba2: Mamba2 + weight-shared attention block).  Training applies
go through the taps engine so DP per-example gradients cover every
parameter; serving paths (prefill / decode with KV or recurrent state) use
a no-op tapper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.tapper import Tapper, scan_with_taps
from repro.launch.sharding import shard_act
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ssm as ssmlib
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # init

    def _attn_init(self, key):
        c = self.cfg
        if c.mla:
            return attn.mla_init(
                key, c.d_model, c.n_heads, q_lora_rank=c.q_lora_rank,
                kv_lora_rank=c.kv_lora_rank, qk_nope_dim=c.qk_nope_dim,
                qk_rope_dim=c.qk_rope_dim, v_head_dim=c.v_head_dim,
                dtype=c.jdtype)
        return attn.gqa_init(key, c.d_model, c.n_heads, c.n_kv, c.hd,
                             qk_norm=c.qk_norm, bias=c.attn_bias,
                             dtype=c.jdtype)

    def _attn_block_init(self, key):
        c = self.cfg
        ks = jax.random.split(key, 4)
        p = {"attn": self._attn_init(ks[0]),
             "ln1": cm.norm_init(ks[2], c.d_model, c.norm, c.jdtype),
             "ln2": cm.norm_init(ks[3], c.d_model, c.norm, c.jdtype)}
        if c.n_experts:
            p["moe"] = moe_init(ks[1], c.d_model, c.d_ff, c.n_experts,
                                n_shared=c.n_shared_experts, dtype=c.jdtype)
        else:
            p["mlp"] = mlp_init(ks[1], c.d_model, c.d_ff, c.mlp,
                                dtype=c.jdtype)
        return {k: v for k, v in p.items() if v is not None}

    def init(self, key):
        c = self.cfg
        ks = jax.random.split(key, 6)
        tree = {"tok_emb": {"emb": cm.mk(
            ks[0], (c.padded_vocab, c.d_model), ("vocab", "embed"),
            scale=0.02, dtype=c.jdtype)}}

        if c.family in ("dense", "moe", "vlm"):
            tree["blocks"] = cm.stack_layers(ks[1], c.n_layers,
                                             self._attn_block_init)
        elif c.family == "ssm":        # xLSTM
            k_every = c.slstm_every or 0
            if k_every:
                n_super = c.n_layers // k_every

                def super_init(k):
                    k1, k2, k3 = jax.random.split(k, 3)
                    return {
                        "m": cm.stack_layers(k1, k_every - 1, lambda kk: {
                            "blk": ssmlib.mlstm_init(
                                kk, c.d_model, expand=c.ssm_expand,
                                d_conv=c.ssm_conv, n_heads=c.n_heads,
                                dtype=c.jdtype),
                            "ln": cm.norm_init(kk, c.d_model, c.norm, c.jdtype)}),
                        "s": {"blk": ssmlib.slstm_init(
                                  k2, c.d_model, n_heads=c.n_heads,
                                  dtype=c.jdtype),
                              "ln": cm.norm_init(k3, c.d_model, c.norm, c.jdtype)},
                    }

                tree["blocks"] = cm.stack_layers(ks[1], n_super, super_init)
            else:
                tree["blocks"] = cm.stack_layers(ks[1], c.n_layers, lambda kk: {
                    "blk": ssmlib.mlstm_init(
                        kk, c.d_model, expand=c.ssm_expand, d_conv=c.ssm_conv,
                        n_heads=c.n_heads, dtype=c.jdtype),
                    "ln": cm.norm_init(kk, c.d_model, c.norm, c.jdtype)})
        elif c.family == "hybrid":     # Zamba2
            n_super = c.n_layers // c.attn_every
            tree["blocks"] = cm.stack_layers(ks[1], n_super, lambda k: {
                "mamba": cm.stack_layers(k, c.attn_every, lambda kk: {
                    "blk": ssmlib.mamba2_init(
                        kk, c.d_model, d_state=c.ssm_state,
                        expand=c.ssm_expand, d_conv=c.ssm_conv,
                        dtype=c.jdtype),
                    "ln": cm.norm_init(kk, c.d_model, c.norm, c.jdtype)})})
            k1, k2, k3, k4 = jax.random.split(ks[2], 4)
            tree["shared"] = {
                "attn": attn.gqa_init(k1, c.d_model, c.n_heads, c.n_kv, c.hd,
                                      qk_norm=c.qk_norm, dtype=c.jdtype),
                "mlp": mlp_init(k2, c.d_model, c.d_ff, c.mlp, dtype=c.jdtype),
                "ln1": cm.norm_init(k3, c.d_model, c.norm, c.jdtype),
                "ln2": cm.norm_init(k4, c.d_model, c.norm, c.jdtype)}
        else:
            raise ValueError(c.family)

        fn = cm.norm_init(ks[3], c.d_model, c.norm, c.jdtype)
        if fn is not None:
            tree["final_norm"] = fn
        if not c.tie_embeddings:
            tree["head"] = {"w": cm.mk(ks[4], (c.d_model, c.padded_vocab),
                                       ("embed", "vocab"), scale=0.02,
                                       dtype=c.jdtype)}
        return cm.split_tree(tree)

    # ------------------------------------------------------------------
    # shared pieces

    def _attn_kw(self, mode="train"):
        c = self.cfg
        return dict(n_heads=c.n_heads, n_kv=c.n_kv, head_dim=c.hd,
                    rope_theta=c.rope_theta, qk_norm=c.qk_norm,
                    attn_impl=c.attn_impl, dp_attn=c.dp_attn)

    def _head(self, tp, params, h):
        c = self.cfg
        if c.tie_embeddings:
            return tp.dense("~tok_emb", h, params["tok_emb"]["emb"],
                            w_transposed=True, param_key="emb")
        return tp.dense("head", h, params["head"]["w"])

    def _backbone_train(self, params, h, tp: Tapper):
        c = self.cfg
        B = h.shape[0]
        lb0 = jnp.zeros((B,), jnp.float32)

        if c.family in ("dense", "moe", "vlm"):
            def body(stp, carry, p_l, _):
                hh, lb = carry
                hh = cm.shard_hidden(hh)
                a, _ = attn.gqa_apply(
                    stp, "attn", p_l["attn"],
                    cm.apply_norm(stp, "ln1", p_l.get("ln1"), hh, c.norm),
                    **self._attn_kw()) if not c.mla else attn.mla_apply(
                    stp, "attn", p_l["attn"],
                    cm.apply_norm(stp, "ln1", p_l.get("ln1"), hh, c.norm),
                    n_heads=c.n_heads, q_lora_rank=c.q_lora_rank,
                    kv_lora_rank=c.kv_lora_rank, qk_nope_dim=c.qk_nope_dim,
                    qk_rope_dim=c.qk_rope_dim, v_head_dim=c.v_head_dim,
                    rope_theta=c.rope_theta, attn_impl=c.attn_impl,
                    dp_attn=c.dp_attn)
                hh = hh + a
                x2 = cm.apply_norm(stp, "ln2", p_l.get("ln2"), hh, c.norm)
                if c.n_experts:
                    m, lb_l = moe_apply(stp, "moe", p_l["moe"], x2,
                                        impl=c.moe_impl, n_experts=c.n_experts,
                                        topk=c.topk,
                                        capacity_factor=c.capacity_factor)
                    lb = lb + lb_l
                else:
                    m = mlp_apply(stp, "mlp", p_l["mlp"], x2, c.mlp)
                return (hh + m, lb)

            (h, lb) = scan_with_taps(tp, "blocks", body, (h, lb0),
                                     params["blocks"], remat=c.remat)
            return h, lb

        if c.family == "ssm":
            if c.slstm_every:
                def body(stp, carry, p_l, _):
                    hh, lb = carry

                    def mbody(sstp, hhh, pm, _):
                        z = cm.apply_norm(sstp, "ln", pm.get("ln"), hhh, c.norm)
                        return hhh + ssmlib.mlstm_apply(
                            sstp, "blk", pm["blk"], z, expand=c.ssm_expand,
                            d_conv=c.ssm_conv, n_heads=c.n_heads)

                    hh = scan_with_taps(stp, "m", mbody, hh, p_l["m"])
                    z = cm.apply_norm(stp, "s/ln", p_l["s"].get("ln"), hh,
                                      c.norm)
                    hh = hh + ssmlib.slstm_apply(stp, "s/blk", p_l["s"]["blk"],
                                                 z, n_heads=c.n_heads)
                    return (hh, lb)
            else:
                def body(stp, carry, p_l, _):
                    hh, lb = carry
                    z = cm.apply_norm(stp, "ln", p_l.get("ln"), hh, c.norm)
                    hh = hh + ssmlib.mlstm_apply(
                        stp, "blk", p_l["blk"], z, expand=c.ssm_expand,
                        d_conv=c.ssm_conv, n_heads=c.n_heads)
                    return (hh, lb)

            (h, lb) = scan_with_taps(tp, "blocks", body, (h, lb0),
                                     params["blocks"], remat=c.remat)
            return h, lb

        if c.family == "hybrid":
            def body(stp, carry, p_l, _, shared):
                hh, lb = carry

                def mbody(sstp, hhh, pm, _):
                    z = cm.apply_norm(sstp, "ln", pm.get("ln"), hhh, c.norm)
                    return hhh + ssmlib.mamba2_apply(
                        sstp, "blk", pm["blk"], z, d_state=c.ssm_state,
                        expand=c.ssm_expand, d_conv=c.ssm_conv)

                hh = scan_with_taps(stp, "mamba", mbody, hh, p_l["mamba"])
                z = cm.apply_norm(stp, "~shared/ln1", shared.get("ln1"), hh,
                                  c.norm)
                a, _ = attn.gqa_apply(stp, "~shared/attn", shared["attn"], z,
                                      window=c.window, **self._attn_kw())
                hh = hh + a
                z = cm.apply_norm(stp, "~shared/ln2", shared.get("ln2"), hh,
                                  c.norm)
                hh = hh + mlp_apply(stp, "~shared/mlp", shared["mlp"], z,
                                    c.mlp)
                return (hh, lb)

            (h, lb) = scan_with_taps(tp, "blocks", body, (h, lb0),
                                     params["blocks"], remat=c.remat,
                                     shared_params=params["shared"])
            return h, lb

        raise ValueError(c.family)

    # ------------------------------------------------------------------
    # training apply: per-example losses

    def apply(self, params, batch, tp: Tapper):
        c = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        mask = batch.get("mask")
        h = tp.embed("tok_emb", params["tok_emb"]["emb"], tokens)
        h = cm.shard_hidden(h)
        h, lb = self._backbone_train(params, h, tp)
        h = cm.apply_norm(tp, "final_norm", params.get("final_norm"), h,
                          c.norm)
        logits = self._head(tp, params, h)
        logits = shard_act(logits, "batch", "seq", "vocab")
        losses = cm.per_example_xent(logits, labels, mask,
                                     vocab_valid=c.vocab)
        if c.n_experts:
            losses = losses + c.moe_lb_coef * lb / max(c.n_layers, 1)
        return losses

    # ------------------------------------------------------------------
    # serving: caches, prefill, decode

    def init_cache(self, batch: int, max_len: int):
        c = self.cfg
        dt = c.jdtype

        if c.family in ("dense", "moe", "vlm"):
            L = c.n_layers
            if c.mla:
                one = attn.mla_cache(batch, max_len, c.kv_lora_rank,
                                     c.qk_rope_dim, dt)
            else:
                one = attn.gqa_cache(batch, max_len, c.n_kv, c.hd, dt)
            one.pop("pos")
            layers = jax.tree.map(
                lambda a: jnp.zeros((L,) + a.shape, a.dtype), one)
            return {"layers": layers, "pos": jnp.zeros((), jnp.int32)}

        if c.family == "ssm":
            if c.slstm_every:
                n_super = c.n_layers // c.slstm_every
                m1 = ssmlib.mlstm_state(batch, c.d_model, expand=c.ssm_expand,
                                        d_conv=c.ssm_conv, n_heads=c.n_heads,
                                        dtype=dt)
                s1 = ssmlib.slstm_state(batch, c.d_model)
                layers = {
                    "m": jax.tree.map(lambda a: jnp.zeros(
                        (n_super, c.slstm_every - 1) + a.shape, a.dtype), m1),
                    "s": jax.tree.map(lambda a: jnp.zeros(
                        (n_super,) + a.shape, a.dtype), s1)}
            else:
                m1 = ssmlib.mlstm_state(batch, c.d_model, expand=c.ssm_expand,
                                        d_conv=c.ssm_conv, n_heads=c.n_heads,
                                        dtype=dt)
                layers = jax.tree.map(
                    lambda a: jnp.zeros((c.n_layers,) + a.shape, a.dtype), m1)
            return {"layers": layers, "pos": jnp.zeros((), jnp.int32)}

        if c.family == "hybrid":
            n_super = c.n_layers // c.attn_every
            m1 = ssmlib.mamba2_state(batch, c.d_model, d_state=c.ssm_state,
                                     expand=c.ssm_expand, d_conv=c.ssm_conv,
                                     dtype=dt)
            w = min(max_len, c.window) if c.window else max_len
            a1 = attn.gqa_cache(batch, w, c.n_kv, c.hd, dt)
            a1.pop("pos")
            layers = {
                "mamba": jax.tree.map(lambda a: jnp.zeros(
                    (n_super, c.attn_every) + a.shape, a.dtype), m1),
                "attn": jax.tree.map(lambda a: jnp.zeros(
                    (n_super,) + a.shape, a.dtype), a1)}
            return {"layers": layers, "pos": jnp.zeros((), jnp.int32)}

        raise ValueError(c.family)

    def _block_step(self, params_l, cache_l, h, pos, shared=None):
        """One layer applied to new tokens h (B,T,D) against its cache."""
        c = self.cfg
        tp = Tapper()
        if c.family in ("dense", "moe", "vlm"):
            cl = dict(cache_l)
            cl["pos"] = pos
            z = cm.apply_norm(tp, "ln1", params_l.get("ln1"), h, c.norm)
            if c.mla:
                a, nc = attn.mla_apply(
                    tp, "attn", params_l["attn"], z, n_heads=c.n_heads,
                    q_lora_rank=c.q_lora_rank, kv_lora_rank=c.kv_lora_rank,
                    qk_nope_dim=c.qk_nope_dim, qk_rope_dim=c.qk_rope_dim,
                    v_head_dim=c.v_head_dim, rope_theta=c.rope_theta,
                    cache=cl, absorbed_decode=c.mla_absorbed_decode)
            else:
                a, nc = attn.gqa_apply(tp, "attn", params_l["attn"], z,
                                       cache=cl, window=0, **self._attn_kw())
            h = h + a
            z = cm.apply_norm(tp, "ln2", params_l.get("ln2"), h, c.norm)
            if c.n_experts:
                m, _ = moe_apply(tp, "moe", params_l["moe"], z,
                                 impl=c.moe_impl, n_experts=c.n_experts,
                                 topk=c.topk,
                                 capacity_factor=c.capacity_factor)
            else:
                m = mlp_apply(tp, "mlp", params_l["mlp"], z, c.mlp)
            nc.pop("pos")
            return h + m, nc

        if c.family == "ssm":
            # h (B,1,D) single-token step
            x = h[:, 0]
            if c.slstm_every:
                def mstep(xx, pm_cm):
                    pm, cm_ = pm_cm
                    z = _norm_plain(pm.get("ln"), xx, c.norm)
                    y, ns = ssmlib.mlstm_step(pm["blk"], cm_, z,
                                              expand=c.ssm_expand,
                                              d_conv=c.ssm_conv,
                                              n_heads=c.n_heads)
                    return xx + y, ns

                x, ns_m = lax.scan(mstep, x,
                                   (params_l["m"], cache_l["m"]))
                z = _norm_plain(params_l["s"].get("ln"), x, c.norm)
                y, ns_s = ssmlib.slstm_step(params_l["s"]["blk"],
                                            cache_l["s"], z,
                                            n_heads=c.n_heads)
                x = x + y
                return x[:, None], {"m": ns_m, "s": ns_s}
            z = _norm_plain(params_l.get("ln"), x, c.norm)
            y, ns = ssmlib.mlstm_step(params_l["blk"], cache_l, z,
                                      expand=c.ssm_expand, d_conv=c.ssm_conv,
                                      n_heads=c.n_heads)
            return (x + y)[:, None], ns

        if c.family == "hybrid":
            x = h[:, 0]

            def mstep(xx, pm_cm):
                pm, cm_ = pm_cm
                z = _norm_plain(pm.get("ln"), xx, c.norm)
                y, ns = ssmlib.mamba2_step(pm["blk"], cm_, z,
                                           d_state=c.ssm_state,
                                           expand=c.ssm_expand,
                                           d_conv=c.ssm_conv)
                return xx + y, ns

            x, ns_m = lax.scan(mstep, x,
                               (params_l["mamba"], cache_l["mamba"]))
            hh = x[:, None]
            cl = dict(cache_l["attn"])
            cl["pos"] = pos
            z = _norm_plain3(shared.get("ln1"), hh, c.norm)
            a, nc = attn.gqa_apply(Tapper(), "attn", shared["attn"], z,
                                   cache=cl, window=c.window,
                                   **self._attn_kw())
            hh = hh + a
            z = _norm_plain3(shared.get("ln2"), hh, c.norm)
            hh = hh + mlp_apply(Tapper(), "mlp", shared["mlp"], z, c.mlp)
            nc.pop("pos")
            return hh, {"mamba": ns_m, "attn": nc}

        raise ValueError(c.family)

    def decode_step(self, params, cache, tokens):
        """tokens (B,) -> (logits (B, V), new cache)."""
        c = self.cfg
        h = params["tok_emb"]["emb"][tokens][:, None, :]   # (B,1,D)
        pos = cache["pos"]
        shared = params.get("shared")

        def body(hh, xs):
            p_l, c_l = xs
            hh, nc = self._block_step(p_l, c_l, hh, pos, shared)
            return hh, nc

        h, new_layers = lax.scan(body, h, (params["blocks"], cache["layers"]))
        tp = Tapper()
        h = cm.apply_norm(tp, "fn", params.get("final_norm"), h, c.norm)
        logits = self._head(tp, params, h)[:, 0]
        return logits, {"layers": new_layers, "pos": pos + 1}

    def prefill(self, params, tokens, max_len: int):
        """tokens (B, T_prompt) -> (last-token logits, cache)."""
        c = self.cfg
        B, T = tokens.shape
        cache = self.init_cache(B, max_len)
        if c.family in ("dense", "moe", "vlm"):
            h = params["tok_emb"]["emb"][tokens]
            pos = cache["pos"]
            shared = params.get("shared")

            def body(hh, xs):
                p_l, c_l = xs
                hh, nc = self._block_step(p_l, c_l, hh, pos, shared)
                return hh, nc

            h, new_layers = lax.scan(body, h,
                                     (params["blocks"], cache["layers"]))
            tp = Tapper()
            if c.prefill_last_only:
                # Head matmul on the last position only: the (T, V) logits
                # tensor (and its vocab-TP collective) drops to (1, V).
                h = h[:, -1:]
            h = cm.apply_norm(tp, "fn", params.get("final_norm"), h, c.norm)
            logits = self._head(tp, params, h)[:, -1]
            return logits, {"layers": new_layers,
                            "pos": pos + T}
        # recurrent families: sequential prefill via decode steps
        def step(carry, tok_t):
            cch = carry
            logits, cch = self.decode_step(params, cch, tok_t)
            return cch, logits

        cache, logits_all = lax.scan(step, cache, jnp.moveaxis(tokens, 1, 0))
        return logits_all[-1], cache

    # ------------------------------------------------------------------
    # dry-run input specs

    def train_input_specs(self, shape: ShapeSpec):
        B, T = shape.global_batch, shape.seq_len
        return {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, T), jnp.int32)}

    def decode_input_specs(self, shape: ShapeSpec):
        B, S = shape.global_batch, shape.seq_len
        cache = jax.eval_shape(lambda: self.init_cache(B, S))
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}

    def prefill_input_specs(self, shape: ShapeSpec):
        B, S = shape.global_batch, shape.seq_len
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def _norm_plain(p, x, kind):
    """Norm on (B, D) without taps (decode paths)."""
    return cm.apply_norm(Tapper(), "n", p, x, kind)


def _norm_plain3(p, x, kind):
    return cm.apply_norm(Tapper(), "n", p, x, kind)
