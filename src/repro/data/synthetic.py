"""Deterministic synthetic datasets + Poisson subsampling for DP.

Examples are pure functions of (seed, index) — no state, no files — so any
host can materialize any shard and restarts are exactly reproducible; this
is the property a 1000-node data pipeline needs (the loader never
checkpoints data state, only the step counter).

DP-SGD's privacy amplification assumes Poisson sampling: each example is
included independently with rate q per step.  ``poisson_batch_indices``
implements that (deterministically per step), padding/truncating to a
fixed batch size for shape-stable jit with a mask for the padding.
"""
from __future__ import annotations

import numpy as np


def _rng(seed: int, *salt: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=abs(hash((seed,) + salt))
                                                % (1 << 63)))


class SyntheticLMDataset:
    """Deterministic token streams with local n-gram structure (so loss can
    actually decrease) over ``vocab`` tokens."""

    def __init__(self, vocab: int, seq_len: int, n_examples: int = 1 << 16,
                 seed: int = 0):
        self.vocab, self.seq_len, self.n = vocab, seq_len, n_examples
        self.seed = seed

    def __len__(self):
        return self.n

    @property
    def _perm(self):
        if not hasattr(self, "_perm_cache"):
            self._perm_cache = _rng(self.seed, 12345).permutation(self.vocab)
        return self._perm_cache

    def example(self, idx: int) -> dict:
        g = _rng(self.seed, int(idx))
        # ε-noisy global bigram: next = perm[cur] w.p. 0.9, else uniform —
        # a learnable signal (optimal loss ≈ 0.1·lnV + H(0.1)) so training
        # tests can assert decrease.
        perm = self._perm
        toks = np.empty(self.seq_len + 1, np.int64)
        toks[0] = g.integers(0, self.vocab)
        noise = g.random(self.seq_len) < 0.1
        rand = g.integers(0, self.vocab, self.seq_len)
        for t in range(self.seq_len):
            toks[t + 1] = rand[t] if noise[t] else perm[toks[t]]
        return {"tokens": toks[:-1].astype(np.int32),
                "labels": toks[1:].astype(np.int32)}

    def batch(self, indices) -> dict:
        exs = [self.example(i) for i in indices]
        return {k: np.stack([e[k] for e in exs]) for k in exs[0]}


class SyntheticImageDataset:
    """Class-conditional Gaussian blobs (CNN examples/benchmarks)."""

    def __init__(self, img_size: int = 32, n_classes: int = 10,
                 n_examples: int = 1 << 14, seed: int = 0):
        self.img, self.k, self.n, self.seed = img_size, n_classes, n_examples, seed
        g = _rng(seed, 999)
        self.protos = g.normal(0, 1, (n_classes, 3, img_size, img_size))

    def __len__(self):
        return self.n

    def example(self, idx: int) -> dict:
        g = _rng(self.seed, int(idx))
        y = int(g.integers(0, self.k))
        x = self.protos[y] + g.normal(0, 0.8, self.protos[y].shape)
        return {"img": x.astype(np.float32), "label": np.int32(y)}

    def batch(self, indices) -> dict:
        exs = [self.example(i) for i in indices]
        return {"img": np.stack([e["img"] for e in exs]),
                "label": np.stack([e["label"] for e in exs])}


def poisson_batch_indices(step: int, n_examples: int, rate: float,
                          fixed_batch: int, seed: int = 0):
    """Deterministic Poisson subsample for one step.

    Returns (indices (fixed_batch,), mask (fixed_batch,)): sampled examples
    padded (mask 0) or truncated to the fixed jit batch size.
    """
    g = _rng(seed, 7, step)
    draw = g.random(n_examples) < rate
    idx = np.nonzero(draw)[0]
    g.shuffle(idx)
    idx = idx[:fixed_batch]
    mask = np.zeros(fixed_batch, np.float32)
    mask[: len(idx)] = 1.0
    out = np.zeros(fixed_batch, np.int64)
    out[: len(idx)] = idx
    return out, mask
