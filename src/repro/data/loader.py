"""Shard-aware prefetching loader."""
from __future__ import annotations

import queue
import threading

import numpy as np


def shard_for_host(indices, host_id: int, n_hosts: int):
    """Static round-robin shard of a batch's example indices."""
    return indices[host_id::n_hosts]


class PrefetchLoader:
    """Background-thread prefetch of deterministic batches.

    ``batch_fn(step) -> pytree`` must be pure; the loader owns no data
    state, so resuming from step k is just ``PrefetchLoader(batch_fn,
    start_step=k)``.
    """

    def __init__(self, batch_fn, start_step: int = 0, prefetch: int = 2):
        self.batch_fn = batch_fn
        self.step = start_step
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.batch_fn(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self._stop.set()
