from repro.data.synthetic import (SyntheticImageDataset, SyntheticLMDataset,
                                  poisson_batch_indices)
from repro.data.loader import PrefetchLoader, shard_for_host

__all__ = ["SyntheticImageDataset", "SyntheticLMDataset",
           "poisson_batch_indices", "PrefetchLoader", "shard_for_host"]
