"""Calibration tables: measured hardware constants the planner trusts.

A :class:`Calibration` is the persisted result of one
:func:`repro.calibrate.harness.measure` run on a concrete
(hardware, mesh) pair: the FLOP rate, HBM streaming bandwidth,
per-mesh-axis collective bandwidth at the stash sizes plans actually
move, and the Pallas kernel sweep winners (the ``pe_conv_grad``
VMEM-budget sweep).  The cost model converts these into
FLOP-equivalents-per-byte lookups that replace the analytic constants
whenever a calibration is active (:mod:`repro.core.costmodel` keeps the
analytic values as the documented fallback).

Fail-safe discipline mirrors the plan store's: every deserialized blob
is validated — wrong format or truncated payload, non-finite or
non-positive rates, a hardware signature or mesh that does not match the
live context — and each rejection raises a *named* error
(:class:`CalibrationFormatError`, :class:`CalibrationValueError`,
:class:`CalibrationHardwareMismatch`, :class:`CalibrationMeshMismatch`)
rather than being silently planned against.  Soft consumers (engine
init, CLI flags) catch :class:`CalibrationError`, emit a
:class:`CalibrationFallbackWarning`, and plan with the analytic table;
the strict loaders never downgrade an error to a warning themselves.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import time
import warnings
from typing import Any, Mapping

import jax

from repro.core import costmodel

CALIBRATION_FORMAT_VERSION = 1


class CalibrationError(ValueError):
    """Base class for every calibration rejection (named subclasses)."""


class CalibrationFormatError(CalibrationError):
    """The blob is not a readable calibration: wrong/missing format
    version, missing required fields, or a truncated/undecodable payload."""


class CalibrationValueError(CalibrationError):
    """A measured rate is unusable: NaN, infinite, zero, or negative.
    Planning against such a value would divide by it (or price the wire
    at nothing), so the blob is rejected whole."""


class CalibrationHardwareMismatch(CalibrationError):
    """The blob was measured on different hardware than this process
    runs on; its bandwidths say nothing about the live machine."""


class CalibrationMeshMismatch(CalibrationError):
    """The blob was measured for a different mesh topology; its per-axis
    collective bandwidths do not describe the topology being planned."""


class CalibrationFallbackWarning(UserWarning):
    """Emitted (never raised) when a soft consumer falls back to the
    analytic constants because a calibration was absent or rejected."""


class CalibrationAxisFallbackWarning(UserWarning):
    """Emitted when multi-axis collective traffic is priced through the
    legacy axis-less (slowest-axis) lookup.  On a 2D mesh the slowest
    axis misprices every byte that crosses a faster axis — call sites
    that know which axis a collective crosses must name it."""


def hardware_signature() -> str:
    """Identity of the hardware this process runs on — what a stored
    calibration is keyed to.  Backend + device kind + device count: a
    calibration measured on another signature is rejected, not reused."""
    devs = jax.devices()
    kind = getattr(devs[0], "device_kind", "unknown")
    return f"{jax.default_backend()}:{kind}:{len(devs)}"


def _finite_pos(value, name: str) -> float:
    try:
        v = float(value)
    except (TypeError, ValueError):
        raise CalibrationValueError(
            f"calibration field {name!r} is not a number: {value!r}")
    if not math.isfinite(v) or v <= 0.0:
        raise CalibrationValueError(
            f"calibration field {name!r} must be a finite positive rate, "
            f"got {value!r}")
    return v


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Measured hardware constants for one (hardware, mesh) pair.

    Rates are measured, not assumed:
      * ``flops_per_second``             — dense matmul throughput;
      * ``hbm_bytes_per_second``         — streaming read+write bandwidth;
      * ``collective_bytes_per_second``  — per mesh-axis *wire* bandwidth
        (ring bytes-on-the-wire per device per second, the same
        convention the cost model charges), ``{}`` off-mesh;
      * ``kernels``                      — per-kernel sweep results, e.g.
        ``{"pe_conv_grad": {"vmem_budget": 4194304, ...}}``.

    ``source`` records provenance: ``"measured"`` (harness),
    ``"injected"`` (tests/benchmarks feeding known timings), or
    ``"replan"`` (derived by the engine's mispredict loop from an
    observed step time).
    """

    hardware: str
    mesh: tuple = ()
    flops_per_second: float = 0.0
    hbm_bytes_per_second: float = 0.0
    collective_bytes_per_second: dict = dataclasses.field(
        default_factory=dict)
    kernels: dict = dataclasses.field(default_factory=dict)
    measured_at: float = 0.0
    source: str = "measured"

    def __post_init__(self):
        object.__setattr__(self, "mesh", costmodel.mesh_axes(self.mesh))
        _finite_pos(self.flops_per_second, "flops_per_second")
        _finite_pos(self.hbm_bytes_per_second, "hbm_bytes_per_second")
        for axis, bw in dict(self.collective_bytes_per_second).items():
            _finite_pos(bw, f"collective_bytes_per_second[{axis!r}]")

    # -- cost-model lookups ------------------------------------------------

    def collective_flops_per_byte(self, axis: str | None = None) -> float:
        """FLOP-equivalents of one collective byte on the wire, for the
        mesh axis the collective actually crosses.  The axis-less form is
        legacy: exact for single-axis calibrations, but on a multi-axis
        calibration it prices *all* traffic at the slowest measured axis
        and emits :class:`CalibrationAxisFallbackWarning` — cost-model
        call sites name the axis instead."""
        table = self.collective_bytes_per_second
        if not table:
            raise CalibrationValueError(
                f"calibration {self.digest()} has no collective "
                f"measurements (mesh {costmodel.format_mesh(self.mesh)})")
        if axis is not None:
            if axis not in table:
                raise CalibrationMeshMismatch(
                    f"calibration {self.digest()} has no measurement for "
                    f"mesh axis {axis!r}; measured axes: {sorted(table)}")
            return self.flops_per_second / table[axis]
        if len(table) > 1:
            warnings.warn(
                f"calibration {self.digest()} measured "
                f"{len(table)} mesh axes {sorted(table)} but was asked "
                f"for an axis-less wire price; pricing all traffic at "
                f"the slowest axis — name the axis the collective "
                f"crosses", CalibrationAxisFallbackWarning, stacklevel=2)
        return self.flops_per_second / min(table.values())

    def hbm_flops_per_byte(self) -> float:
        return self.flops_per_second / self.hbm_bytes_per_second

    def seconds_for_flops(self, flops_equiv: float) -> float:
        return float(flops_equiv) / self.flops_per_second

    # -- identity / validation ---------------------------------------------

    def digest(self) -> str:
        """Content hash of the measured values — what plan fingerprints
        fold in, so a plan built under different measured constants keys
        (and fails safe) exactly like a plan built from different code."""
        payload = dict(self.to_payload())
        payload.pop("measured_at", None)   # identity is the values
        return hashlib.sha1(
            json.dumps(payload, sort_keys=True).encode()).hexdigest()[:12]

    def validate_for(self, hardware: str | None = None, mesh=None):
        """Reject this calibration for a live context it does not
        describe, naming what differs."""
        if hardware is not None and self.hardware != hardware:
            raise CalibrationHardwareMismatch(
                f"calibration {self.digest()} was measured on "
                f"{self.hardware!r}, this process runs on {hardware!r}; "
                f"re-calibrate on this hardware")
        if mesh is not None:
            ms = costmodel.mesh_axes(mesh)
            if self.mesh != ms:
                raise CalibrationMeshMismatch(
                    f"calibration {self.digest()} was measured for mesh "
                    f"{costmodel.format_mesh(self.mesh)}, this process "
                    f"plans {costmodel.format_mesh(ms)}; re-calibrate "
                    f"for this topology")

    # -- serialization -----------------------------------------------------

    def to_payload(self) -> dict:
        return {
            "format": CALIBRATION_FORMAT_VERSION,
            "hardware": self.hardware,
            "mesh": [[n, s] for n, s in self.mesh],
            "flops_per_second": self.flops_per_second,
            "hbm_bytes_per_second": self.hbm_bytes_per_second,
            "collective_bytes_per_second":
                dict(self.collective_bytes_per_second),
            "kernels": self.kernels,
            "measured_at": self.measured_at,
            "source": self.source,
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_payload(), **kw)

    @classmethod
    def from_payload(cls, p: Any) -> "Calibration":
        if not isinstance(p, Mapping):
            raise CalibrationFormatError(
                f"calibration payload is not a mapping: {type(p).__name__}")
        if p.get("format") != CALIBRATION_FORMAT_VERSION:
            raise CalibrationFormatError(
                f"unsupported calibration format {p.get('format')!r} "
                f"(this build reads {CALIBRATION_FORMAT_VERSION})")
        required = ("hardware", "flops_per_second", "hbm_bytes_per_second",
                    "collective_bytes_per_second")
        missing = [k for k in required if k not in p]
        if missing:
            raise CalibrationFormatError(
                f"calibration payload is missing fields {missing} "
                f"(truncated or foreign blob)")
        try:
            return cls(
                hardware=str(p["hardware"]),
                mesh=tuple((str(n), int(s)) for n, s in p.get("mesh", [])),
                flops_per_second=p["flops_per_second"],
                hbm_bytes_per_second=p["hbm_bytes_per_second"],
                collective_bytes_per_second={
                    str(k): v
                    for k, v in p["collective_bytes_per_second"].items()},
                kernels=dict(p.get("kernels", {})),
                measured_at=float(p.get("measured_at", 0.0)),
                source=str(p.get("source", "measured")))
        except CalibrationError:
            raise
        except (TypeError, ValueError, AttributeError) as e:
            raise CalibrationFormatError(
                f"malformed calibration payload: {e}") from e

    @classmethod
    def from_json(cls, s: str) -> "Calibration":
        try:
            payload = json.loads(s)
        except json.JSONDecodeError as e:
            raise CalibrationFormatError(
                f"calibration blob is not valid JSON (truncated?): "
                f"{e}") from e
        return cls.from_payload(payload)

    # -- derivation --------------------------------------------------------

    def retimed(self, *, predicted_s: float, measured_s: float,
                coll_bytes: float,
                coll_bytes_by_axis=None) -> "Calibration":
        """A calibration updated so the cost model would have predicted
        ``measured_s`` for the step it predicted ``predicted_s`` for —
        the engine's mispredict feedback.  When the step moved collective
        bytes, the gap is attributed to the wire (the term the analytic
        model most mis-prices); otherwise the FLOP rate absorbs it.
        ``coll_bytes_by_axis`` (``(("data", bytes), ...)`` from the
        plan's per-axis breakdown) prices the old wire share on the axes
        the traffic actually crossed; without it the legacy axis-less
        lookup prices the scalar total (and warns on multi-axis
        calibrations).  Deterministic: a pure function of its inputs."""
        predicted_s = _finite_pos(predicted_s, "predicted_s")
        measured_s = _finite_pos(measured_s, "measured_s")
        table = self.collective_bytes_per_second
        by_axis = dict(coll_bytes_by_axis or ())
        if table and (by_axis or coll_bytes > 0.0):
            # Solve for the wire bandwidth that closes the gap, holding
            # the compute terms fixed.  The compute share of the
            # prediction is predicted_s minus the old wire share.
            if by_axis:
                wire_s_old = sum(float(b) / table[a]
                                 for a, b in by_axis.items() if a in table)
            else:
                old_fpb = self.collective_flops_per_byte()
                wire_s_old = self.seconds_for_flops(old_fpb * coll_bytes)
            if wire_s_old > 0.0:
                compute_s = max(predicted_s - wire_s_old, 1e-12)
                wire_s_new = max(measured_s - compute_s, 1e-12)
                scale = wire_s_old / wire_s_new
                new_table = {axis: bw * scale for axis, bw in table.items()}
                return dataclasses.replace(
                    self, collective_bytes_per_second=new_table,
                    source="replan", measured_at=self.measured_at)
        scale = predicted_s / measured_s
        return dataclasses.replace(
            self, flops_per_second=self.flops_per_second * scale,
            source="replan", measured_at=self.measured_at)


# ---------------------------------------------------------------------------
# Process-wide registry: (hardware, mesh) -> Calibration.  The engine and
# the cost model consult it when no calibration is passed explicitly;
# load_plan_store() installs the calibrations persisted with a plan store.


_REGISTRY: dict[tuple, Calibration] = {}


def register(calib: Calibration) -> Calibration:
    _REGISTRY[(calib.hardware, calib.mesh)] = calib
    return calib


def lookup(mesh=None, hardware: str | None = None) -> Calibration | None:
    """The registered calibration for (live hardware, this mesh), or
    ``None``.  Exact-mesh match only: a ``data:8`` calibration never
    silently prices a ``data:4`` plan."""
    hw = hardware if hardware is not None else hardware_signature()
    return _REGISTRY.get((hw, costmodel.mesh_axes(mesh)))


def registered() -> list:
    return list(_REGISTRY.values())


def clear_registry():
    _REGISTRY.clear()


def load_calibration(path: str, *, expect_hardware: bool = True,
                     expect_mesh=None) -> Calibration:
    """Strict file loader: parse, validate values, and check the blob
    against the live context.  Raises named :class:`CalibrationError`
    subclasses; never warns-and-continues (that is the caller's choice,
    see :func:`repro.calibrate.load_or_fallback`)."""
    with open(path) as f:
        raw = f.read()
    calib = Calibration.from_json(raw)
    calib.validate_for(
        hardware=hardware_signature() if expect_hardware else None,
        mesh=expect_mesh)
    return calib


def save_calibration(path: str, calib: Calibration):
    with open(path, "w") as f:
        f.write(calib.to_json(indent=1))


def injected(*, mesh=(), flops_per_second: float = 1e12,
             hbm_bytes_per_second: float = 1e11,
             collective_bytes_per_second=None,
             kernels: dict | None = None,
             hardware: str | None = None) -> Calibration:
    """A synthetic calibration for tests/benchmarks: known rates on the
    *live* hardware signature (so context validation passes), marked
    ``source="injected"``.  ``collective_bytes_per_second`` may be a
    single float (applied to every mesh axis) or a per-axis mapping."""
    ms = costmodel.mesh_axes(mesh)
    coll = collective_bytes_per_second
    if coll is None:
        coll = {}
    if not isinstance(coll, Mapping):
        coll = {name: float(coll) for name, _ in ms}
    return Calibration(
        hardware=hardware or hardware_signature(), mesh=ms,
        flops_per_second=flops_per_second,
        hbm_bytes_per_second=hbm_bytes_per_second,
        collective_bytes_per_second=dict(coll),
        kernels=dict(kernels or {}), measured_at=time.time(),
        source="injected")
