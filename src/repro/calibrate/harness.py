"""Microbenchmark harness: measure the constants the planner uses.

One :func:`measure` run produces a :class:`~repro.calibrate.table.Calibration`
for the live (hardware, mesh) pair:

  * dense matmul FLOP rate — the unit every other cost converts into;
  * HBM streaming bandwidth (one read + one write over a large array);
  * per-mesh-axis collective bandwidth: a ring all-reduce over that
    axis's device count, timed at the shard sizes plans actually move
    (stash traffic is MBs per device, not the microscopic latency
    regime), reported as *wire* bytes per device per second — the same
    ``ring(d) * shard_bytes`` convention the cost model charges;
  * Pallas kernel sweeps: ``gram_norm_fused`` wall time and the pending
    ``pe_conv_grad`` VMEM-budget sweep from ``kernels/ops.py`` (the
    winning budget feeds :func:`repro.kernels.ops.vmem_budget`).

Everything is timed through ``jax.jit`` + ``block_until_ready`` with a
compile warmup, min-of-iters.  The harness never guesses: an axis it
cannot measure (more devices than the host has) raises a named
:class:`~repro.calibrate.table.CalibrationMeshMismatch` instead of
inventing a bandwidth.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel
from repro.calibrate.table import (Calibration, CalibrationMeshMismatch,
                                   hardware_signature)

# Shard sizes (bytes per device) the ring all-reduce is timed at: the
# small end catches latency-bound axes, the large end the stash-traffic
# streaming regime plans actually buy.
COLLECTIVE_SIZES = (1 << 20, 8 << 20)
COLLECTIVE_SIZES_QUICK = (1 << 20,)
# pe_conv_grad VMEM budgets swept (bytes); VMEM_BUDGET's default 8 MiB
# sits in the middle so the sweep can move it either way.
VMEM_SWEEP = (1 << 20, 2 << 20, 4 << 20, 8 << 20, 16 << 20)


def _time(f, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_flops_per_second(*, quick: bool = False) -> float:
    """Dense f32 matmul throughput (the cost model's FLOP unit)."""
    n = 256 if quick else 1024
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda x, y: x @ y)
    t = _time(f, a, a, iters=2 if quick else 4)
    return 2.0 * n ** 3 / max(t, 1e-9)


def measure_hbm_bytes_per_second(*, quick: bool = False) -> float:
    """Streaming read+write bandwidth over an array far beyond cache."""
    elems = (4 << 20 if quick else 32 << 20) // 4
    x = jnp.ones((elems,), jnp.float32)
    f = jax.jit(lambda v: v * 1.0000001)
    t = _time(f, x, iters=2 if quick else 4)
    return 2.0 * 4.0 * elems / max(t, 1e-9)


def measure_collective_bytes_per_second(axis: str, size: int, *,
                                        sizes=COLLECTIVE_SIZES) -> float:
    """Ring all-reduce wire bandwidth over ``size`` devices: per-device
    bytes-on-the-wire (``ring(d) * shard_bytes``) per second, the
    convention :mod:`repro.core.costmodel` charges collective traffic
    at.  The best rate over the size sweep is reported (the streaming
    regime, which is what stash traffic sees)."""
    devs = jax.devices()
    if size > len(devs):
        raise CalibrationMeshMismatch(
            f"cannot measure collective bandwidth for mesh axis "
            f"{axis}:{size} — this host has {len(devs)} device(s); "
            f"measure on the target topology")
    if size < 2:
        raise CalibrationMeshMismatch(
            f"mesh axis {axis}:{size} induces no collective traffic; "
            f"nothing to measure")
    sub = devs[:size]
    f = jax.pmap(lambda v: jax.lax.psum(v, "i"), axis_name="i",
                 devices=sub)
    ring = costmodel._ring(size)
    best = 0.0
    for shard_bytes in sizes:
        elems = max(shard_bytes // 4, 1)
        x = jnp.ones((size, elems), jnp.float32)
        t = _time(f, x, iters=3)
        best = max(best, ring * 4.0 * elems / max(t, 1e-9))
    return best


def sweep_pe_conv_vmem(*, quick: bool = False,
                       budgets=VMEM_SWEEP) -> dict:
    """The pending ``VMEM_BUDGET`` sweep: time ``pe_conv_grad`` under
    each candidate budget's autotuned output-channel tile and report the
    winner.  Budgets that resolve to the same tile share one timing."""
    from repro.kernels import ops as kops

    B, C, D, HW, K = (2, 8, 16, 12, 3) if quick else (4, 16, 32, 16, 3)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(B, C, HW, HW), jnp.float32)
    out_sp = HW - K + 1
    dy = jnp.asarray(rng.randn(B, D, out_sp, out_sp), jnp.float32)
    by_bd: dict[int, float] = {}
    sweep: dict[str, dict] = {}
    for budget in budgets:
        bd = kops._autotune_bd(D, C, (HW, HW), (out_sp, out_sp), (K, K),
                               budget)
        if bd not in by_bd:
            f = jax.jit(lambda a, b, _bd=bd: kops._pc.pe_conv_grad_2d(
                a, b, KH=K, KW=K, bd=_bd, interpret=not kops.on_tpu()))
            by_bd[bd] = _time(f, x, dy, iters=2 if quick else 3)
        sweep[str(budget)] = {"bd": bd, "seconds": by_bd[bd]}
    winner = min(sweep, key=lambda k: sweep[k]["seconds"])
    return {"vmem_budget": int(winner), "bd": sweep[winner]["bd"],
            "sweep": sweep}


def time_gram_norm_fused(*, quick: bool = False) -> dict:
    from repro.kernels import ops as kops

    B, T, Dm = (2, 64, 32) if quick else (4, 256, 128)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(B, T, Dm), jnp.float32)
    dy = jnp.asarray(rng.randn(B, T, Dm), jnp.float32)
    w = jnp.asarray(rng.rand(B), jnp.float32)
    f = jax.jit(lambda a, b, c: kops.gram_norm_fused(a, b, c))
    t = _time(f, x, dy, w, iters=2 if quick else 3)
    return {"seconds": t, "shape": [B, T, Dm]}


def measure(mesh=None, *, quick: bool = False, kernels: bool = True,
            collective_sizes=None) -> Calibration:
    """Run the full harness on the live hardware for ``mesh`` and return
    the resulting :class:`Calibration` (not registered — callers decide;
    see :func:`repro.calibrate.get_or_measure`)."""
    axes = costmodel.mesh_axes(mesh)
    sizes = collective_sizes or (COLLECTIVE_SIZES_QUICK if quick
                                 else COLLECTIVE_SIZES)
    coll = {name: measure_collective_bytes_per_second(name, size,
                                                      sizes=sizes)
            for name, size in axes if size > 1}
    kern = {}
    if kernels:
        kern["pe_conv_grad"] = sweep_pe_conv_vmem(quick=quick)
        kern["gram_norm_fused"] = time_gram_norm_fused(quick=quick)
    return Calibration(
        hardware=hardware_signature(), mesh=axes,
        flops_per_second=measure_flops_per_second(quick=quick),
        hbm_bytes_per_second=measure_hbm_bytes_per_second(quick=quick),
        collective_bytes_per_second=coll, kernels=kern,
        measured_at=time.time(), source="measured")
