"""Measured-cost calibration: the planner trusts the hardware.

``repro.calibrate`` closes the loop the analytic cost model leaves open:

  * :mod:`~repro.calibrate.harness` measures FLOP rate, HBM bandwidth,
    per-mesh-axis collective bandwidth, and the Pallas kernel sweeps on
    the live hardware;
  * :mod:`~repro.calibrate.table` holds the validated, serializable
    :class:`Calibration` result and the process-wide registry the cost
    model consults;
  * :func:`load_or_fallback` / :func:`get_or_measure` are the soft entry
    points engines and CLIs use — a bad blob degrades to the analytic
    constants with a named :class:`CalibrationFallbackWarning`, never a
    crash, while the strict loaders in ``table`` never downgrade.
"""
from __future__ import annotations

import warnings

from repro.calibrate.table import (  # noqa: F401  (public re-exports)
    CALIBRATION_FORMAT_VERSION, Calibration,
    CalibrationAxisFallbackWarning, CalibrationError,
    CalibrationFallbackWarning, CalibrationFormatError,
    CalibrationHardwareMismatch, CalibrationMeshMismatch,
    CalibrationValueError, clear_registry, hardware_signature, injected,
    load_calibration, lookup, register, registered, save_calibration)
from repro.calibrate.harness import measure  # noqa: F401


def load_or_fallback(path: str, *, mesh=None,
                     expect_hardware: bool = True):
    """Load + validate a stored calibration; on *any* failure (missing
    file, truncated blob, wrong hardware/mesh, bad rates) emit a named
    :class:`CalibrationFallbackWarning` and return ``None`` so the
    caller plans with the analytic constants.  The fail-safe entry
    point: planning is degraded, never silently wrong."""
    try:
        return load_calibration(path, expect_hardware=expect_hardware,
                                expect_mesh=mesh)
    except (OSError, CalibrationError) as e:
        warnings.warn(
            f"calibration {path!r} unusable ({type(e).__name__}: {e}); "
            f"falling back to analytic cost constants",
            CalibrationFallbackWarning, stacklevel=2)
        return None


def get_or_measure(mesh=None, *, quick: bool = True) -> Calibration:
    """The registered calibration for (live hardware, mesh), measuring
    and registering one if absent — what first engine init uses."""
    calib = lookup(mesh)
    if calib is None:
        calib = register(measure(mesh, quick=quick))
    return calib
