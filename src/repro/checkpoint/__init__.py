from repro.checkpoint.checkpointer import (Checkpointer, CheckpointCorrupt,
                                           DPTrainState)

__all__ = ["Checkpointer", "CheckpointCorrupt", "DPTrainState"]
