"""Fault-tolerant checkpointing: atomic, async, keep-k, reshard-on-load.

Layout per step::

    <dir>/step_000123/
        manifest.json      # step, leaf paths, shapes/dtypes, crc32
        arrays.npz         # one entry per flattened pytree leaf
    <dir>/LATEST           # atomically-updated pointer

Writes go to ``step_X.tmp`` then ``os.rename`` (atomic on POSIX) so a
crash mid-write can never corrupt the restore point — the fault-tolerance
contract the runtime layer relies on.  ``save_async`` runs serialization
in a background thread (double-buffered: at most one outstanding save).

On a multi-host cluster each host would write only its addressable shards
(same manifest schema, one arrays file per host); restore then reassembles
and ``jax.device_put``s onto the *current* mesh — which is also the
elastic-rescale path: checkpoints are mesh-agnostic, so restoring onto a
smaller/larger mesh reshards automatically.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return {jax.tree_util.keystr(kp): np.asarray(v) for kp, v in leaves}


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        flat = _flatten(tree)
        name = f"step_{step:09d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "crc32": zlib.crc32(np.ascontiguousarray(v)
                                               .tobytes()) & 0xFFFFFFFF}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(name)
        os.rename(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def save_async(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async
        self._thread = threading.Thread(target=self.save,
                                        args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def latest_step(self) -> int | None:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        name = open(p).read().strip()
        if not os.path.exists(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, like_tree, step: int | None = None, *,
                shardings=None, verify: bool = True):
        """Restore into the structure of ``like_tree``; optionally place
        onto ``shardings`` (elastic re-mesh: any mesh works)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        data = np.load(os.path.join(d, "arrays.npz"))
        if verify:
            for k, meta in manifest["leaves"].items():
                crc = zlib.crc32(np.ascontiguousarray(data[k]).tobytes()) \
                    & 0xFFFFFFFF
                if crc != meta["crc32"]:
                    raise IOError(f"checkpoint corruption in {k}")
        leaves = jax.tree_util.tree_leaves_with_path(like_tree)
        out = []
        for kp, leaf in leaves:
            arr = data[jax.tree_util.keystr(kp)]
            out.append(np.asarray(arr).astype(leaf.dtype)
                       if hasattr(leaf, "dtype") else arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like_tree), out)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree, manifest["step"]
