"""Fault-tolerant checkpointing: atomic, async, keep-k, reshard-on-load.

Layout per step::

    <dir>/step_000123/
        manifest.json      # step, leaf paths, shapes/dtypes, crc32
        arrays.npz         # one entry per flattened pytree leaf
        meta.json          # optional JSON sidecar (CRC'd via the manifest)
    <dir>/LATEST           # atomically-updated pointer

Writes go to ``step_X.tmp`` then ``os.rename`` (atomic on POSIX) so a
crash mid-write can never corrupt the restore point — the fault-tolerance
contract the runtime layer relies on.  ``save_async`` runs serialization
in a background thread (double-buffered: at most one outstanding save).

Restore verifies every leaf's CRC32 (and the meta sidecar's) and raises
the named :class:`CheckpointCorrupt` on any mismatch, truncation, or
missing entry; with ``fallback=True`` a corrupt step is skipped (loudly,
via the logger) and the previous keep-k checkpoint is tried instead, so
one bad write never strands a run.

:class:`DPTrainState` is the unit of DP-training persistence: params and
optimizer state, the cross-step clipping state (stale coefficients,
auto-budget quantiles), the privacy accountant ledger, the plan
fingerprint, the monitor state, and the noise-stream seed.  A restart
that restores all of it — and replays the deterministic noise stream —
is bit-identical to a run that never died (tests/test_resume_equivalence
is the differential proof).

On a multi-host cluster each host would write only its addressable shards
(same manifest schema, one arrays file per host); restore then reassembles
and ``jax.device_put``s onto the *current* mesh — which is also the
elastic-rescale path: checkpoints are mesh-agnostic, so restoring onto a
smaller/larger mesh reshards automatically.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import shutil
import threading
import zipfile
import zlib
from typing import Any

import jax
import numpy as np

log = logging.getLogger("repro.checkpoint")


class CheckpointCorrupt(IOError):
    """A checkpoint failed CRC verification or cannot be read at all
    (truncated arrays file, missing leaves, unparseable manifest/meta)."""


@dataclasses.dataclass
class DPTrainState:
    """Everything a DP training step stream needs to resume bit-exactly.

    ``clip_state`` holds the engine's cross-step clipping arrays (any of
    ``prev_norms_sq`` / ``budgets`` / ``budget_q``); ``ledger`` is the
    accountant's ``state_dict()``; ``plan_fingerprint`` pins the ExecPlan
    (mesh included) the checkpoint was produced under so a resume can
    distinguish "same plan" from "elastic re-plan" from "model changed";
    ``run_seed`` pins the deterministic noise stream."""

    params: Any
    opt: Any
    clip_state: dict = dataclasses.field(default_factory=dict)
    ledger: dict | None = None
    plan_fingerprint: str = ""
    monitor: dict | None = None
    run_seed: int | None = None
    mesh_axes: tuple = ()


class _AnyLeaf:
    """Restore-verbatim placeholder for leaves whose shape/dtype only the
    checkpoint knows (the clip-state arrays)."""


def _flatten(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return {jax.tree_util.keystr(kp): np.asarray(v) for kp, v in leaves}


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _meta_bytes(meta: dict) -> bytes:
    return json.dumps(meta, sort_keys=True).encode()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree, *, meta: dict | None = None) -> str:
        flat = {k: v for k, v in _flatten(tree).items()}
        name = f"step_{step:09d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "crc32": _crc(v)}
                       for k, v in flat.items()},
        }
        if meta is not None:
            mb = _meta_bytes(meta)
            with open(os.path.join(tmp, "meta.json"), "wb") as f:
                f.write(mb)
            manifest["meta_crc32"] = zlib.crc32(mb) & 0xFFFFFFFF
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(name)
        os.rename(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def save_async(self, step: int, tree, *, meta: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async
        self._thread = threading.Thread(target=self.save,
                                        args=(step, host_tree),
                                        kwargs={"meta": meta}, daemon=True)
        self._thread.start()

    def save_state(self, step: int, state: DPTrainState) -> str:
        tree, meta = self._state_payload(state)
        return self.save(step, tree, meta=meta)

    def save_state_async(self, step: int, state: DPTrainState):
        tree, meta = self._state_payload(state)
        self.save_async(step, tree, meta=meta)

    def _state_payload(self, state: DPTrainState):
        clip = {k: np.asarray(v) for k, v in (state.clip_state or {}).items()
                if v is not None}
        tree = {"params": state.params, "opt": state.opt, "clip": clip}
        meta = {"ledger": state.ledger,
                "plan_fingerprint": state.plan_fingerprint,
                "monitor": state.monitor,
                "run_seed": state.run_seed,
                "mesh_axes": [[n, int(s)] for n, s in state.mesh_axes],
                "clip_keys": sorted(clip)}
        return tree, meta

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def latest_step(self) -> int | None:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        name = open(p).read().strip()
        if not os.path.exists(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def available_steps(self) -> list[int]:
        """All completed checkpoint steps, newest first (from the atomic
        directory listing, not the LATEST pointer, so a crash between the
        two renames still sees the newest completed step)."""
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    steps.append(int(d.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps, reverse=True)

    def _candidates(self, step: int | None, fallback: bool) -> list[int]:
        if step is not None:
            return [step]
        steps = self.available_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        return steps if fallback else steps[:1]

    def _load_manifest(self, d: str) -> dict:
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                return json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointCorrupt(f"unreadable manifest in {d}: {e}") \
                from e

    def read_meta(self, step: int | None = None) -> dict | None:
        """The CRC-verified meta sidecar of a checkpoint (None if the
        checkpoint was written without one)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        return self._read_meta_dir(d, self._load_manifest(d))

    def _read_meta_dir(self, d: str, manifest: dict) -> dict | None:
        if "meta_crc32" not in manifest:
            return None
        try:
            with open(os.path.join(d, "meta.json"), "rb") as f:
                mb = f.read()
        except OSError as e:
            raise CheckpointCorrupt(f"missing meta.json in {d}: {e}") from e
        if (zlib.crc32(mb) & 0xFFFFFFFF) != manifest["meta_crc32"]:
            raise CheckpointCorrupt(f"meta.json CRC mismatch in {d}")
        try:
            return json.loads(mb)
        except ValueError as e:
            raise CheckpointCorrupt(f"unparseable meta.json in {d}: {e}") \
                from e

    def _restore_dir(self, step: int, like_tree, *, shardings=None,
                     verify: bool = True):
        """Restore one checkpoint directory or raise CheckpointCorrupt."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        if not os.path.isdir(d):
            raise FileNotFoundError(f"no checkpoint for step {step} in "
                                    f"{self.dir}")
        manifest = self._load_manifest(d)
        try:
            data = np.load(os.path.join(d, "arrays.npz"))
            if verify:
                for k, m in manifest["leaves"].items():
                    if _crc(data[k]) != m["crc32"]:
                        raise CheckpointCorrupt(
                            f"checkpoint corruption in {k} (step {step}): "
                            f"CRC mismatch")
            leaves = jax.tree_util.tree_leaves_with_path(like_tree)
            out = []
            for kp, leaf in leaves:
                arr = data[jax.tree_util.keystr(kp)]
                out.append(np.asarray(arr).astype(leaf.dtype)
                           if hasattr(leaf, "dtype") else np.asarray(arr))
        except CheckpointCorrupt:
            raise
        except (OSError, KeyError, ValueError, zlib.error,
                zipfile.BadZipFile) as e:
            # truncated zip, missing member, undecodable payload — all the
            # shapes a torn write takes
            raise CheckpointCorrupt(
                f"unreadable checkpoint step {step}: "
                f"{type(e).__name__}: {e}") from e
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like_tree), out)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree

    def restore(self, like_tree, step: int | None = None, *,
                shardings=None, verify: bool = True,
                fallback: bool = False):
        """Restore into the structure of ``like_tree``; optionally place
        onto ``shardings`` (elastic re-mesh: any mesh works).  CRC failure
        raises :class:`CheckpointCorrupt`; ``fallback=True`` skips corrupt
        steps (with a logged warning) and tries the previous keep-k
        checkpoint instead."""
        last_err = None
        for s in self._candidates(step, fallback):
            try:
                return self._restore_dir(s, like_tree, shardings=shardings,
                                         verify=verify), s
            except CheckpointCorrupt as e:
                last_err = e
                if not fallback:
                    raise
                log.warning("checkpoint step %d corrupt (%s); falling back "
                            "to the previous checkpoint", s, e)
        raise last_err

    def restore_state(self, like_params, like_opt,
                      step: int | None = None, *, shardings=None,
                      fallback: bool = True):
        """Restore a :class:`DPTrainState` (params/opt shaped like the
        given trees; clip-state arrays restored verbatim from the
        checkpoint).  Corrupt steps fall back to older checkpoints by
        default — a restart should prefer losing a few steps of progress
        to dying on a torn write.  Returns ``(state, step)``."""
        last_err = None
        for s in self._candidates(step, fallback):
            d = os.path.join(self.dir, f"step_{s:09d}")
            try:
                meta = self._read_meta_dir(d, self._load_manifest(d)) or {}
                like = {"params": like_params, "opt": like_opt,
                        "clip": {k: _AnyLeaf()
                                 for k in meta.get("clip_keys", ())}}
                tree = self._restore_dir(s, like, shardings=shardings)
            except CheckpointCorrupt as e:
                last_err = e
                if not fallback:
                    raise
                log.warning("checkpoint step %d corrupt (%s); falling back "
                            "to the previous checkpoint", s, e)
                continue
            state = DPTrainState(
                params=tree["params"], opt=tree["opt"],
                clip_state=tree["clip"], ledger=meta.get("ledger"),
                plan_fingerprint=meta.get("plan_fingerprint", ""),
                monitor=meta.get("monitor"),
                run_seed=meta.get("run_seed"),
                mesh_axes=tuple((n, int(sz))
                                for n, sz in meta.get("mesh_axes", ())))
            return state, s
        raise last_err
