"""Optimizers (pure functions over pytrees).

Moments are float32 regardless of parameter dtype; updates are computed in
float32 and cast back (bf16 params keep a de-facto fp32 master through the
fp32 moments + fp32 arithmetic — a full master copy is a config away but
doubles state).  Under pjit the states inherit the parameter shardings, so
with FSDP-sharded parameters this *is* ZeRO: every state shard lives
exactly once across the mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, *, lr=1e-4, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def sgdm_init(params):
    return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params),
            "step": jnp.zeros((), jnp.int32)}


def sgdm_update(grads, state, params, *, lr=0.1, momentum=0.9,
                weight_decay=0.0):
    def upd(g, mo, p):
        g = g.astype(jnp.float32)
        if weight_decay:
            g = g + weight_decay * p.astype(jnp.float32)
        mo = momentum * mo + g
        return (p.astype(jnp.float32) - lr * mo).astype(p.dtype), mo

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mom"])
    out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
    return (treedef.unflatten([o[0] for o in out]),
            {"mom": treedef.unflatten([o[1] for o in out]),
             "step": state["step"] + 1})
