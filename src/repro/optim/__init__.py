from repro.optim.adamw import (adamw_init, adamw_update, sgdm_init,
                               sgdm_update)
from repro.optim.schedule import cosine_schedule, linear_warmup

__all__ = ["adamw_init", "adamw_update", "sgdm_init", "sgdm_update",
           "cosine_schedule", "linear_warmup"]
