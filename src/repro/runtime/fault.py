"""Fault tolerance: restart-from-checkpoint orchestration.

At thousand-node scale the failure model is "some host dies every few
hours"; the recovery contract is (1) checkpoints are atomic and frequent,
(2) the training loop is a pure function of (state, step), so recovery =
reload latest state and replay the deterministic data stream from there.
``run_with_restarts`` implements that loop; ``ChaosMonkey`` injects
failures for tests and drills.
"""
from __future__ import annotations

import logging
import time

log = logging.getLogger("repro.runtime")


class WorkerFailure(RuntimeError):
    """Simulated/propagated node failure."""


class ChaosMonkey:
    def __init__(self, fail_at_steps=(), seed: int = 0, p: float = 0.0):
        self.fail_at = set(fail_at_steps)
        self.p = p
        import random
        self._rng = random.Random(seed)
        self.tripped = 0

    def maybe_fail(self, step: int):
        if step in self.fail_at or (self.p and self._rng.random() < self.p):
            self.fail_at.discard(step)
            self.tripped += 1
            raise WorkerFailure(f"injected failure at step {step}")


def run_with_restarts(train_segment, *, max_restarts: int = 3,
                      backoff_s: float = 0.0):
    """``train_segment(restart_count) -> result`` runs until completion or
    raises; on failure we restart (the segment is responsible for restoring
    from its checkpointer).  Returns (result, restarts_used)."""
    restarts = 0
    while True:
        try:
            return train_segment(restarts), restarts
        except WorkerFailure as e:
            restarts += 1
            log.warning("worker failure: %s (restart %d/%d)", e, restarts,
                        max_restarts)
            if restarts > max_restarts:
                raise
            if backoff_s:
                time.sleep(backoff_s)
