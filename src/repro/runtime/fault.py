"""Fault tolerance: restart-from-checkpoint orchestration.

At thousand-node scale the failure model is "some host dies every few
hours"; the recovery contract is (1) checkpoints are atomic and frequent,
(2) the training loop is a pure function of (state, step) — deterministic
noise streams, checkpointed clip/accountant state — so recovery = reload
the latest valid ``DPTrainState`` and replay the deterministic step
stream from there.  ``run_with_restarts`` implements that loop with a
configurable catchable-exception set, jittered exponential backoff, and
a sliding restart-budget window; ``ChaosMonkey`` injects failures for
tests and ``train.py --chaos`` drills.
"""
from __future__ import annotations

import logging
import random
import time
from collections import deque

log = logging.getLogger("repro.runtime")


class WorkerFailure(RuntimeError):
    """Simulated/propagated node failure."""


class ChaosMonkey:
    """Deterministic failure injection: trip at fixed steps and/or with
    per-step probability ``p`` (seeded, so a chaos drill is replayable).
    ``exc`` picks what is raised — pair it with ``run_with_restarts``'s
    ``catch`` set to drill both recoverable faults and hard kills."""

    def __init__(self, fail_at_steps=(), seed: int = 0, p: float = 0.0,
                 exc=WorkerFailure):
        self.fail_at = set(fail_at_steps)
        self.p = p
        self.exc = exc
        self._rng = random.Random(seed)
        self.tripped = 0

    def maybe_fail(self, step: int):
        if step in self.fail_at or (self.p and self._rng.random() < self.p):
            self.fail_at.discard(step)
            self.tripped += 1
            raise self.exc(f"injected failure at step {step}")


def backoff_delay(attempt: int, *, base_s: float, cap_s: float = 60.0,
                  jitter: float = 0.5, rng=None) -> float:
    """Jittered exponential backoff: ``min(cap, base·2^(attempt-1))``
    stretched by up to ``jitter``× (decorrelates a fleet of restarting
    workers so they don't stampede the checkpoint store in lockstep)."""
    if base_s <= 0.0:
        return 0.0
    d = min(cap_s, base_s * (2.0 ** max(attempt - 1, 0)))
    if jitter:
        d *= 1.0 + jitter * (rng.random() if rng is not None
                             else random.random())
    return d


def run_with_restarts(train_segment, *, max_restarts: int = 3,
                      catch=(WorkerFailure,), backoff_s: float = 0.0,
                      backoff_cap_s: float = 60.0, jitter: float = 0.5,
                      restart_window_s: float | None = None,
                      seed: int = 0, sleep=time.sleep,
                      clock=time.monotonic):
    """``train_segment(restart_count) -> result`` runs until completion or
    raises; on a *caught* failure we restart (the segment is responsible
    for restoring from its checkpointer).  Returns (result, restarts_used).

    ``catch``            exception types that trigger a restart; anything
                         else propagates immediately (a hard kill).
    ``backoff_s``        base of the jittered exponential backoff between
                         restarts (0 = restart immediately).
    ``restart_window_s`` budget the restarts over a sliding window: only
                         failures within the last window count against
                         ``max_restarts``, so a long healthy run doesn't
                         die on its (max_restarts+1)-th lifetime fault —
                         ``None`` budgets over the whole run.
    ``sleep``/``clock``  injectable for tests.
    """
    catch = tuple(catch) if isinstance(catch, (tuple, list)) else (catch,)
    rng = random.Random(seed)
    restarts = 0
    window: deque[float] = deque()
    while True:
        try:
            return train_segment(restarts), restarts
        except catch as e:
            restarts += 1
            now = clock()
            window.append(now)
            if restart_window_s is not None:
                while window and window[0] < now - restart_window_s:
                    window.popleft()
            used = len(window) if restart_window_s is not None else restarts
            log.warning("worker failure: %s (restart %d, budget %d/%d%s)",
                        e, restarts, used, max_restarts,
                        f" in {restart_window_s:g}s window"
                        if restart_window_s is not None else "")
            if used > max_restarts:
                raise
            delay = backoff_delay(used, base_s=backoff_s,
                                  cap_s=backoff_cap_s, jitter=jitter,
                                  rng=rng)
            if delay > 0:
                sleep(delay)
