"""Step-time monitoring + straggler detection."""
from __future__ import annotations

import time


class StepMonitor:
    """EMA of step wall-time; flags stragglers (steps slower than
    ``threshold``× the EMA).  On a real cluster each host reports its step
    time through a heartbeat store and the controller compares across
    hosts; here the same logic runs per process and is unit-tested."""

    def __init__(self, alpha: float = 0.1, threshold: float = 3.0):
        self.alpha = alpha
        self.threshold = threshold
        self.ema: float | None = None
        self.stragglers: list[tuple[int, float]] = []
        self.replans: list[tuple[int, float]] = []
        self._t0: float | None = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        self.observe(step, dt)
        return dt

    def observe(self, step: int, dt: float):
        if self.ema is None:
            self.ema = dt
            return
        if dt > self.threshold * self.ema:
            # flagged steps do not poison the EMA baseline
            self.stragglers.append((step, dt))
            return
        self.ema = (1 - self.alpha) * self.ema + self.alpha * dt

    def is_straggler(self, dt: float) -> bool:
        return self.ema is not None and dt > self.threshold * self.ema

    def record_replan(self, step: int, ratio: float):
        """A mispredict re-plan fired (see PrivacyEngine.observe_step_time):
        record (step, measured/predicted ratio) and reset the EMA — the
        new plan's step time is a new baseline, and carrying the old one
        over would flag every post-re-plan step as a straggler (or mask
        a regression) against a dead plan's timings."""
        self.replans.append((int(step), float(ratio)))
        self.ema = None

    # -- checkpoint (de)serialization -----------------------------------
    # The monitor rides along in DPTrainState so straggler history and the
    # EMA baseline survive restarts instead of resetting to cold-start
    # (where the first post-restore step would re-seed the EMA and mask
    # a genuinely degraded host).

    def state_dict(self) -> dict:
        return {"alpha": self.alpha, "threshold": self.threshold,
                "ema": self.ema,
                "stragglers": [[int(s), float(dt)]
                               for s, dt in self.stragglers],
                "replans": [[int(s), float(r)] for s, r in self.replans]}

    def load_state_dict(self, state: dict):
        self.alpha = float(state["alpha"])
        self.threshold = float(state["threshold"])
        self.ema = None if state["ema"] is None else float(state["ema"])
        self.stragglers = [(int(s), float(dt))
                           for s, dt in state["stragglers"]]
        # pre-calibration checkpoints carry no replan history
        self.replans = [(int(s), float(r))
                        for s, r in state.get("replans", [])]
        self._t0 = None

    @classmethod
    def from_state(cls, state: dict) -> "StepMonitor":
        mon = cls()
        mon.load_state_dict(state)
        return mon
