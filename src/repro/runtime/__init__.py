from repro.runtime.fault import (ChaosMonkey, WorkerFailure, backoff_delay,
                                 run_with_restarts)
from repro.runtime.monitor import StepMonitor
from repro.runtime.elastic import elastic_data_degree, elastic_mesh_axes

__all__ = ["ChaosMonkey", "WorkerFailure", "backoff_delay",
           "run_with_restarts", "StepMonitor", "elastic_data_degree",
           "elastic_mesh_axes"]
