from repro.runtime.fault import ChaosMonkey, WorkerFailure, run_with_restarts
from repro.runtime.monitor import StepMonitor
from repro.runtime.elastic import elastic_data_degree

__all__ = ["ChaosMonkey", "WorkerFailure", "run_with_restarts",
           "StepMonitor", "elastic_data_degree"]
