"""Elastic scaling: recompute parallelism after membership changes.

Checkpoints are mesh-agnostic (see checkpointer), so elastic rescale is:
pick the new data-parallel degree that keeps the global batch divisible,
rebuild the mesh, restore onto the new shardings, and continue — the only
state that changes is the per-replica batch slice.
"""
from __future__ import annotations


def elastic_data_degree(n_devices: int, model_par: int, global_batch: int,
                        microbatches: int = 1) -> int:
    """Largest data-parallel degree usable with the surviving devices."""
    if n_devices < model_par:
        raise ValueError(
            f"cannot keep model_par={model_par} with {n_devices} devices")
    data = n_devices // model_par
    micro_global = global_batch // microbatches
    while data > 1 and micro_global % data != 0:
        data -= 1
    return data


def elastic_mesh_axes(prev_axes, n_devices: int, global_batch: int,
                      microbatches: int = 1) -> tuple:
    """The mesh a run checkpointed on ``prev_axes`` should resume on with
    ``n_devices`` surviving: model parallelism is preserved (its sharding
    is baked into the layer math), the data axes collapse to the largest
    degree that still divides the per-microbatch global batch.  Returns
    the normalized axes tuple (``()`` = resume unsharded) — feed it to
    the engine/planner, which re-plans for the new topology while the
    accountant ledger and the deterministic noise stream continue
    unbroken."""
    from repro.core.costmodel import DATA_AXIS_NAMES

    prev = tuple((str(n), int(s)) for n, s in prev_axes)
    if not prev:
        return ()
    model_axes = tuple((n, s) for n, s in prev if n not in DATA_AXIS_NAMES)
    model_par = 1
    for _, s in model_axes:
        model_par *= s
    data = elastic_data_degree(n_devices, model_par, global_batch,
                               microbatches)
    data_name = next((n for n, _ in prev if n in DATA_AXIS_NAMES), "data")
    if data <= 1:
        return model_axes            # () when there was no model axis
    out = []
    placed = False
    for n, s in prev:
        if n in DATA_AXIS_NAMES:
            if not placed:           # collapse all data axes into one
                out.append((data_name, data))
                placed = True
        else:
            out.append((n, s))
    return tuple(out)
