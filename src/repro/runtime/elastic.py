"""Elastic scaling: recompute parallelism after membership changes.

Checkpoints are mesh-agnostic (see checkpointer), so elastic rescale is:
pick the new data-parallel degree that keeps the global batch divisible,
rebuild the mesh, restore onto the new shardings, and continue — the only
state that changes is the per-replica batch slice.
"""
from __future__ import annotations


def elastic_data_degree(n_devices: int, model_par: int, global_batch: int,
                        microbatches: int = 1) -> int:
    """Largest data-parallel degree usable with the surviving devices."""
    if n_devices < model_par:
        raise ValueError(
            f"cannot keep model_par={model_par} with {n_devices} devices")
    data = n_devices // model_par
    micro_global = global_batch // microbatches
    while data > 1 and micro_global % data != 0:
        data -= 1
    return data
