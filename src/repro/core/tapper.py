"""Tap/capture engine for per-example gradient reconstruction.

The chain-rule-based (``crb``) strategy of Rochette et al. (2019) — and the
ghost / book-keeping extensions built on top of it — need, for every
parametric layer, two tensors per example:

  * the layer *input*  ``x_b``   (captured on the forward pass), and
  * the layer *output cotangent* ``δy_b = ∂L_b/∂y_b``.

Autodiff gives us cotangents of anything that is an *input* to the
computation, so every parametric layer adds a zero-valued "tap" to its
output::

    y = x @ W + taps[name]

Differentiating ``Σ_b L_b`` with respect to the taps yields every ``δy_b``
in one standard backward pass (examples are independent, so
``∂(Σ_b L_b)/∂y[b] = ∂L_b/∂y[b]``).  This module provides:

  * :class:`Tapper` — threaded through model ``apply`` functions; applies
    taps, records captures, registers static layer metadata.
  * :func:`scan_with_taps` — ``lax.scan`` over stacked layers with tap
    slicing and capture stacking (nested scans supported).
  * :func:`probe` — shape-only trace (``jax.eval_shape``) discovering tap
    shapes and layer metadata with zero allocation.
  * :func:`capture_backward` — the single backward pass yielding
    (per-example losses, captures, tap cotangents).

Shared parameters (tied embeddings, Zamba2's shared attention block) are
declared by prefixing the tap name with ``"~"``: the parameter path is then
interpreted from the params root and the layer is marked ``shared`` so the
strategies accumulate (and cross-correlate, for norms) all contributions to
the same parameter.

Models stay pure: a ``Tapper`` in mode ``"none"`` is a no-op, so the same
model code serves ordinary training, serving, and every PEG strategy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

TAP_KEY = "__tap__"


# ---------------------------------------------------------------------------
# Pipeline instrumentation
#
# Counts *Python-level* executions of the expensive phases: model forwards,
# backward passes through the model, and shape probes.  Under ``jax.jit``
# these only tick at trace time; calling the strategies eagerly (as the
# tests do) counts real executions per step, which is how the
# one-forward/one-backward steady-state claim of the planned pipeline is
# verified against the 2+2 of the ghost path.


class PipelineStats:
    """Counters for forwards / backwards / probes through a model.

    ``fused`` additionally counts fused norm+contrib realizations
    (``gram_norm_fused``-backed single passes picked by stale-coefficient
    plans); it is not part of :meth:`snapshot`, which covers only the
    whole-model pass counters."""

    __slots__ = ("forwards", "backwards", "probes", "fused")

    def __init__(self):
        self.reset()

    def reset(self):
        self.forwards = 0
        self.backwards = 0
        self.probes = 0
        self.fused = 0

    def snapshot(self) -> dict:
        return {"forwards": self.forwards, "backwards": self.backwards,
                "probes": self.probes}


STATS = PipelineStats()

# ---------------------------------------------------------------------------
# Layer metadata


@dataclasses.dataclass
class LayerMeta:
    """Static description of one tapped layer.

    Attributes:
      kind: "dense" | "embed" | "scale" | "conv" | "local_vjp".
      path: pytree key path of this layer's param dict inside model params.
      param_key: key of the weight inside the layer param dict.
      bias_key: key of the bias (or None).
      w_transposed: "dense" only — weight stored (out, in), used as x @ W.T.
      segmented: captures carry explicit example ids ("seg") instead of a
        leading batch axis (MoE expert layers operate on dispatched slots).
      scanned: number of leading stacked-layer axes on the captures (0 for
        unscanned layers; nested scans add one each).
      shared: parameter is shared across scan steps / call sites (path is
        absolute from the params root; contributions must be *summed over
        applications before* taking norms — dense kinds realize this by
        folding the stacked axes into the sequence axis).
      static: extra static configuration (conv strides, n_examples, ...).
      fn: for "local_vjp": pure ``fn(param_subtree, *inputs) -> y``.
    """

    kind: str
    path: tuple
    param_key: str = "w"
    bias_key: str | None = None
    w_transposed: bool = False
    segmented: bool = False
    scanned: int = 0
    shared: bool = False
    static: dict = dataclasses.field(default_factory=dict)
    fn: Callable | None = None


def _parse_name(name: str) -> tuple[tuple, bool]:
    shared = name.startswith("~")
    return tuple(name.lstrip("~").split("/")), shared


class Tapper:
    """Records captures / applies taps while tracing a model.

    Modes:
      * ``"none"``    — plain forward; taps/captures untouched.
      * ``"probe"``   — record tap output shapes (abstract; use only under
                        ``jax.eval_shape``) plus captures.
      * ``"capture"`` — apply taps (if provided) and record captures.
    """

    def __init__(self, taps=None, mode: str = "none", metas: dict | None = None):
        self.taps = taps
        self.mode = mode
        self.captures: dict = {}
        self.metas: dict[str, LayerMeta] = metas if metas is not None else {}

    # -- core -------------------------------------------------------------
    def tap(self, name: str, y, captures: dict, meta: LayerMeta):
        if self.mode == "none":
            return y
        self.metas.setdefault(name, meta)
        if self.taps is not None and name in self.taps:
            y = y + self.taps[name].astype(y.dtype)
        rec = dict(captures)
        if self.mode == "probe":
            rec[TAP_KEY] = y
        self.captures[name] = rec
        return y

    def active(self) -> bool:
        return self.mode != "none"

    # -- layer helpers ----------------------------------------------------
    def dense(self, name: str, x, w, b=None, *, w_transposed: bool = False,
              param_key: str = "w"):
        """Tapped dense layer ``y = x @ W (+ b)``."""
        y = jnp.matmul(x, w.T if w_transposed else w)
        if b is not None:
            y = y + b
        path, shared = _parse_name(name)
        meta = LayerMeta("dense", path, param_key=param_key,
                         bias_key="b" if b is not None else None,
                         w_transposed=w_transposed, shared=shared)
        return self.tap(name, y, {"x": x}, meta)

    def dense_segmented(self, name: str, x, w, seg, b=None, *,
                        n_examples: int, stacked_axes: int = 1):
        """Dense over dispatched slots: x (*stack, S, Din) with example ids
        seg (*stack, S) and per-group weights w (*stack, Din, Dout) — e.g.
        MoE experts with stack = (E,).  ``stacked_axes`` counts the leading
        group axes (scan over layers adds more automatically)."""
        y = jnp.matmul(x, w)
        if b is not None:
            y = y + b
        path, shared = _parse_name(name)
        meta = LayerMeta("dense", path, bias_key="b" if b is not None else None,
                         segmented=True, shared=shared, scanned=stacked_axes,
                         static={"n_examples": n_examples})
        return self.tap(name, y, {"x": x, "seg": seg}, meta)

    def embed(self, name: str, table, ids):
        y = table[ids]
        path, shared = _parse_name(name)
        meta = LayerMeta("embed", path, param_key="emb", shared=shared)
        return self.tap(name, y, {"ids": ids}, meta)

    def scale(self, name: str, x, g, b=None):
        """Tapped elementwise affine (RMSNorm/LayerNorm): y = x*g (+ b)."""
        y = x * g
        if b is not None:
            y = y + b
        path, shared = _parse_name(name)
        meta = LayerMeta("scale", path, param_key="g",
                         bias_key="b" if b is not None else None, shared=shared)
        return self.tap(name, y, {"x": x}, meta)

    def conv(self, name: str, x, w, b=None, *, stride=1, dilation=1,
             padding=0, groups=1):
        """Tapped N-D convolution, NC(spatial) layout, weight (D, C/g, *K)."""
        from repro.models.convops import conv_forward  # avoid import cycle
        y = conv_forward(x, w, stride=stride, dilation=dilation,
                         padding=padding, groups=groups)
        if b is not None:
            y = y + b.reshape((1, -1) + (1,) * (y.ndim - 2))
        path, shared = _parse_name(name)
        meta = LayerMeta(
            "conv", path, bias_key="b" if b is not None else None, shared=shared,
            static={"stride": stride, "dilation": dilation, "padding": padding,
                    "groups": groups, "kernel_shape": tuple(w.shape)})
        return self.tap(name, y, {"x": x}, meta)

    def local_vjp(self, name: str, fn: Callable, params_sub, *inputs):
        """Tapped generic layer: per-example grads via layer-local VJP under
        vmap.  ``fn(params_sub, *inputs) -> y`` pure; inputs have leading B."""
        y = fn(params_sub, *inputs)
        path, shared = _parse_name(name)
        meta = LayerMeta("local_vjp", path, fn=fn, shared=shared)
        return self.tap(name, y, {"inputs": tuple(inputs)}, meta)


# ---------------------------------------------------------------------------
# Scan integration


def scan_with_taps(tp: Tapper, name: str, body_fn, carry, xs_params,
                   *, xs_extra=None, length=None, remat: bool = False,
                   shared_params=None, unroll: int = 1):
    """``lax.scan`` over stacked layers, threading taps and captures.

    ``body_fn(sub_tp, carry, params_l, extra_l[, shared_params]) -> carry``.
    ``xs_params`` is the stacked (leading L) parameter pytree;
    ``shared_params`` (optional) is an unstacked subtree passed to every
    step — taps against it must use the ``"~"`` absolute-name convention.
    """
    prefix = name + "/"
    taps_l = None
    if tp.taps is not None:
        sub = {k[len(prefix):]: v for k, v in tp.taps.items()
               if k.startswith(prefix)}
        taps_l = sub if sub else None
    sub_metas: dict[str, LayerMeta] = {}

    def body(c, xs):
        p_l, t_l, e_l = xs
        stp = Tapper(t_l, tp.mode, metas=sub_metas)
        if shared_params is None:
            c2 = body_fn(stp, c, p_l, e_l)
        else:
            c2 = body_fn(stp, c, p_l, e_l, shared_params)
        return c2, stp.captures

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    carry, ys = lax.scan(body, carry, (xs_params, taps_l, xs_extra),
                         length=length, unroll=unroll)

    if tp.active():
        for sub_name, cap in ys.items():
            meta = sub_metas[sub_name]
            new_path = meta.path if meta.shared else tuple(name.split("/")) + meta.path
            tp.metas.setdefault(
                prefix + sub_name,
                dataclasses.replace(meta, path=new_path,
                                    scanned=meta.scanned + 1))
            tp.captures[prefix + sub_name] = cap
    return carry


# ---------------------------------------------------------------------------
# Probe and the capture backward pass


def probe(apply_fn, params, batch, *, return_captures: bool = False):
    """Shape-only trace.  Returns (make_taps, metas, tap_shapes) — with
    ``return_captures`` also the per-layer capture shape dicts (tap entry
    stripped), which the execution planner consumes."""
    STATS.probes += 1
    metas: dict[str, LayerMeta] = {}

    def f(p, b):
        tp = Tapper(None, "probe", metas=metas)
        losses = apply_fn(p, b, tp)
        return losses, tp.captures

    _, captures_shape = jax.eval_shape(f, params, batch)

    tap_shapes = {
        n: jax.ShapeDtypeStruct(c[TAP_KEY].shape, c[TAP_KEY].dtype)
        for n, c in captures_shape.items() if TAP_KEY in c
    }

    def make_taps():
        return {n: jnp.zeros(s.shape, s.dtype) for n, s in tap_shapes.items()}

    if return_captures:
        cap_shapes = {n: {k: v for k, v in c.items() if k != TAP_KEY}
                      for n, c in captures_shape.items()}
        return make_taps, metas, tap_shapes, cap_shapes
    return make_taps, metas, tap_shapes


def capture_backward(apply_fn, params, batch, taps, *,
                     with_metas: bool = False):
    """One backward pass → (per-example losses, captures, tap cotangents).

    ``with_metas`` additionally returns the :class:`LayerMeta` dict recorded
    while tracing — the *live* metadata, including ``fn`` references that a
    deserialized :class:`~repro.core.costmodel.ExecPlan` cannot carry."""
    STATS.forwards += 1
    STATS.backwards += 1
    metas: dict[str, LayerMeta] = {}

    def loss_from_taps(t):
        tp = Tapper(t, "capture", metas=metas)
        losses = apply_fn(params, batch, tp)
        return jnp.sum(losses), (losses, tp.captures)

    (_, (losses, caps)), dtaps = jax.value_and_grad(
        loss_from_taps, has_aux=True)(taps)
    if with_metas:
        return losses, caps, dtaps, metas
    return losses, caps, dtaps


# ---------------------------------------------------------------------------
# Pytree path helpers


def get_subtree(tree, path: tuple):
    for k in path:
        tree = tree[k]
    return tree


def set_subtree(tree: dict, path: tuple, value):
    """Functionally set a nested dict entry, creating intermediate dicts."""
    if len(path) == 1:
        out = dict(tree)
        out[path[0]] = value
        return out
    out = dict(tree)
    out[path[0]] = set_subtree(tree.get(path[0], {}), path[1:], value)
    return out
