"""Analytic dispatch between ghost-norm realizations.

The paper's empirical finding is that which per-example-gradient strategy
wins depends on layer geometry (depth, width, batch, kernel size).  Here
that observation becomes an analytic per-layer choice between:

  * ``gram``   — Gram-trick norm, FLOPs ≈ 2·B·T²·(Din+Dout), no per-example
                 gradient materialization (peak extra memory B·chunk·T);
  * ``stream`` — materialize per-example grads then reduce,
                 FLOPs ≈ 4·B·T·Din·Dout, peak extra memory B·Din·Dout;
  * ``rank1``  — no sequence axis: ‖g_b‖² = ‖x_b‖²·‖δy_b‖² exactly.

Defaults target TPU v5e; the memory budget guards HBM blow-ups on the
stream path (the Gram path is always chunk-bounded).
"""
from __future__ import annotations

GRAM_CHUNK = 1024
STREAM_MEM_BUDGET = 2 << 30  # bytes of per-example-grad scratch we tolerate
BYTES = 4


def dense_norm_method(T: int, Di: int, Do: int, B: int,
                      mem_budget: int = STREAM_MEM_BUDGET) -> str:
    if T == 1:
        return "rank1"
    gram_flops = 2 * T * T * (Di + Do)
    stream_flops = 4 * T * Di * Do
    stream_mem = B * Di * Do * BYTES
    if stream_flops < gram_flops and stream_mem <= mem_budget:
        return "stream"
    return "gram"


def seg_norm_method(S: int, Di: int, Do: int, B: int, G: int,
                    mem_budget: int = STREAM_MEM_BUDGET) -> str:
    """MoE expert slots: gram is O(G·S²·(Di+Do+B)), stream is
    O(G·B·Di·Do) FLOPs with (B·Di·Do) scratch per expert-group step."""
    gram_flops = G * S * S * (Di + Do + B)
    stream_flops = G * B * Di * Do
    stream_mem = B * Di * Do * BYTES
    if stream_flops < gram_flops and stream_mem <= mem_budget:
        return "stream"
    return "gram"
