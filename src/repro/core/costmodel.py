"""Per-layer execution planner for the DP-SGD pipeline.

The paper's empirical finding is that which per-example-gradient strategy
wins depends on layer geometry (depth, width, batch, kernel size).  This
module turns that observation into an analytic *per-layer* plan: given the
tapped layers' :class:`~repro.core.tapper.LayerMeta` and capture/cotangent
shapes (from a single shape-only probe), it chooses

  norm phase (per layer)
    * ``gram``   — Gram-trick ghost norm, no per-example gradient
                   materialization (dense: FLOPs ≈ 2·B·T²·(Din+Dout);
                   conv via im2col: 2·B·T²·(C·K/g + D/g)·g);
    * ``stream`` / ``pe`` — materialize per-example grads then reduce
                   (dense: ≈ 4·B·T·Din·Dout; conv: ≈ 4·B·T·(C·K/g)·(D/g)·g),
                   bounded by a peak-memory budget;
    * ``rank1``  — no sequence axis: ‖g_b‖² = ‖x_b‖²·‖δy_b‖² exactly;
    * ``segsum`` / ``gram`` for embedding gathers.

  sum phase (per parameter group)
    * ``stash``    — the norm already materialized per-example grads;
                     keep them and form Σ_b w_b·g_b by a (B,)-weighted
                     reduction (zero recompute);
    * ``contrib``  — weighted per-layer contraction from the captures
                     (the book-keeping path);
    * ``backward`` — take this group's gradient from one shared weighted
                     backward pass; chosen only when the contraction
                     FLOPs exceed the layer's share of a backward by more
                     than the backward's fixed cost (forward recompute +
                     input-cotangent chain), amortized over all such
                     groups.

Plans are cached on (model identity, batch/param shapes, knobs): steady
state training re-plans nothing and never re-probes — see
:func:`get_plan`.  Defaults target TPU v5e; the memory budget guards HBM
blow-ups on the materializing paths (the Gram paths are chunk-bounded).

Mesh-aware planning
-------------------
When a device mesh is supplied (a ``jax.sharding.Mesh``, a
``"data:8,model:2"`` spec string, or an axes mapping — see
:func:`mesh_axes`), every per-layer estimate becomes *per device*: the
batch-linear FLOPs and scratch shrink by the data-parallel degree (the
memory budget is per-device HBM), and each candidate realization is
additionally charged the collective traffic it induces, following the
communication patterns of distributed DP-SGD (Bu et al. 2022):

  * non-materializing norms (gram/ghost/segsum/rank1) all-reduce the
    per-example *scalar* norms — ``B·4`` bytes per layer;
  * materializing (stash) norms put per-example gradients on the
    gradient-sync path — the per-device stash crosses the ring;
  * every group pays its parameter-sized grad-sync all-reduce, and a
    shared weighted backward pays that psum a second time.

Bytes convert to FLOP-equivalents at :data:`COLLECTIVE_FLOPS_PER_BYTE`,
so plan selection can flip per layer under a mesh (e.g. a mid-network
conv whose materializing norm wins on FLOPs loses once its per-example
grads are charged ring traffic).  The mesh shape is folded into the plan
fingerprint and serialized payload: a plan loaded on a different
topology fails loudly (:func:`check_plan_matches`) instead of executing
a stale layout.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import math
import pathlib
from collections import OrderedDict
from fnmatch import fnmatchcase
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core.tapper import LayerMeta, get_subtree, probe

GRAM_CHUNK = 1024
STREAM_MEM_BUDGET = 2 << 30  # bytes of per-example-grad scratch we tolerate
BYTES = 4
# A weighted second backward costs ~2x the forward on top of the wgrad
# contractions it shares with `contrib`; expressed as a multiple of the
# total per-layer wgrad FLOPs (forward ≈ Σ wgrad, dx-chain ≈ Σ wgrad).
BACKWARD_FIXED_FACTOR = 2.0

# --- BEGIN ANALYTIC FALLBACK -------------------------------------------
# The documented fallback table: the ONLY place analytic bandwidth /
# FLOP-rate constants live.  Planning uses resolve_cost_constants(),
# which prefers a measured Calibration (repro.calibrate) for the live
# (hardware, mesh) and falls back to these values when none is
# registered.  CI greps that no magic `*_PER_BYTE = <digits>` constant
# exists outside this block.
#
#   collective_flops_per_byte — interconnect cost of one collective byte
#     in FLOP-equivalents.  TPU v5e: ~197 TFLOP/s bf16 against ~400 GB/s
#     aggregate ICI per chip ≈ 500 FLOPs/byte on the wire; DCN-attached
#     data parallelism is far worse.  BENCH_strategies.json shows this
#     constant can be catastrophically wrong (alexnet@data:8) — which is
#     exactly why measured calibration exists.
#   hbm_flops_per_byte — HBM cost of one byte in FLOP-equivalents (TPU
#     v5e: ~197 TFLOP/s bf16 against ~819 GB/s HBM ≈ 240; kept
#     conservative).  Credits the fused norm+contrib realizations under
#     stale-coefficient clipping: the Gram tiles and the contribution
#     accumulator share one HBM read of the captures.
#   flops_per_second — nominal device throughput used only to convert
#     FLOP-equivalents into predicted seconds when no calibration is
#     active (the mispredict loop needs a time unit).
ANALYTIC_FALLBACK = {
    "collective_flops_per_byte": 512.0,
    "hbm_flops_per_byte": 128.0,
    "flops_per_second": 197.0e12,
}
# --- END ANALYTIC FALLBACK ---------------------------------------------

# Module-level aliases kept for callers/tests that reference the analytic
# values by their historical names.
COLLECTIVE_FLOPS_PER_BYTE = ANALYTIC_FALLBACK["collective_flops_per_byte"]
HBM_FLOPS_PER_BYTE = ANALYTIC_FALLBACK["hbm_flops_per_byte"]
# Mesh axes treated as pure data parallelism (batch-sharded); every other
# axis is model parallelism.
DATA_AXIS_NAMES = ("pod", "data", "batch")


# ---------------------------------------------------------------------------
# Cost constants: calibrated lookups with the analytic table as fallback.
# Every cost term below prices through a CostConstants instance; the only
# question is whether it came from a measured Calibration or from
# ANALYTIC_FALLBACK.


@dataclasses.dataclass(frozen=True)
class CostConstants:
    """The rates one planning pass prices against, plus provenance.
    ``calibration`` is the Calibration digest ("" when analytic) — it is
    folded into plan fingerprints so plans built under different measured
    constants fail safe exactly like plans built from different code.

    ``collective_flops_per_byte_by_axis`` holds the per-mesh-axis wire
    prices (a hashable ``(("data", p), ("model", p))`` tuple) when the
    calibration measured them; :meth:`coll_price` is the per-axis lookup
    every collective cost term goes through, with the scalar
    ``collective_flops_per_byte`` as the fallback for axes that were
    never measured (and for legacy un-axed pricing)."""

    collective_flops_per_byte: float
    hbm_flops_per_byte: float
    flops_per_second: float
    source: str = "analytic"
    calibration: str = ""
    collective_flops_per_byte_by_axis: tuple = ()

    def coll_price(self, axis: str) -> float:
        """Wire price (FLOP-equivalents per byte) for traffic crossing
        ``axis`` — the measured per-axis rate when available, else the
        scalar constant."""
        for name, price in self.collective_flops_per_byte_by_axis:
            if name == axis:
                return price
        return self.collective_flops_per_byte


ANALYTIC_CONSTANTS = CostConstants(
    collective_flops_per_byte=ANALYTIC_FALLBACK["collective_flops_per_byte"],
    hbm_flops_per_byte=ANALYTIC_FALLBACK["hbm_flops_per_byte"],
    flops_per_second=ANALYTIC_FALLBACK["flops_per_second"])


def _resolve_calibration(calibration, mesh):
    """An explicit Calibration wins; ``None`` consults the registry for
    (live hardware, mesh).  Imported lazily — repro.calibrate imports
    this module."""
    if calibration is not None:
        return calibration
    try:
        from repro.calibrate import table as _ct
    except ImportError:      # pragma: no cover - calibrate always ships
        return None
    return _ct.lookup(mesh)


def resolve_cost_constants(calibration=None, mesh=None) -> CostConstants:
    """The :class:`CostConstants` a planning pass for ``mesh`` should
    price against: the given (or registered) calibration's measured
    rates, or :data:`ANALYTIC_CONSTANTS`.  A calibration with no
    collective measurements (e.g. measured off-mesh) keeps the analytic
    wire price — it has nothing better to say about it."""
    calib = _resolve_calibration(calibration, mesh)
    if calib is None:
        return ANALYTIC_CONSTANTS
    if calib.collective_bytes_per_second:
        # Price every measured axis explicitly — the scalar is the
        # slowest axis (max price), kept only as the fallback for axes
        # without a measurement.  Never the axis-less accessor here: that
        # path is the legacy slowest-axis mispricing and warns.
        by_axis = tuple(
            (axis, calib.collective_flops_per_byte(axis))
            for axis in sorted(calib.collective_bytes_per_second))
        coll = max(price for _, price in by_axis)
    else:
        by_axis = ()
        coll = ANALYTIC_FALLBACK["collective_flops_per_byte"]
    return CostConstants(
        collective_flops_per_byte=coll,
        hbm_flops_per_byte=calib.hbm_flops_per_byte(),
        flops_per_second=calib.flops_per_second,
        source=calib.source, calibration=calib.digest(),
        collective_flops_per_byte_by_axis=by_axis)

# contrib for a local_vjp layer replays the layer's VJP once *per
# example* under vmap — for scan-based layers (SSM recurrences) the
# vmapped per-example re-trace lowers far worse than the batched
# backward's single pass, so its contraction is charged a premium over
# the layer's wgrad share.  This is what can tip a local_vjp-dominated
# model into the shared weighted backward.
LOCAL_VJP_CONTRIB_PENALTY = 4.0
PLAN_CACHE_SIZE = 16


# ---------------------------------------------------------------------------
# Mesh normalization: every planner entry point takes ``mesh`` as a
# jax.sharding.Mesh, a "data:8,model:2" spec string, an axes mapping, or
# an (("data", 8), ...) tuple — all normalized to the tuple form, which
# is hashable (cache keys), JSON-able (plan payloads), and fingerprintable.


def _drop_unit_axes(axes: tuple) -> tuple:
    """Size-1 axes are topology no-ops: ``(("data", 8), ("model", 1))``
    executes identically to ``(("data", 8),)``, so they are normalized
    out — otherwise stored plans keyed on one spelling fail safe
    spuriously against the other (`check_plan_matches` compares the
    normalized tuples)."""
    return tuple((n, s) for n, s in axes if int(s) != 1)


def mesh_axes(mesh) -> tuple:
    """Normalize a mesh description to ``(("data", 8), ("model", 2))``.
    Size-1 axes are dropped (see :func:`_drop_unit_axes`)."""
    if mesh is None:
        return ()
    if isinstance(mesh, str):
        out = []
        for part in mesh.split(","):
            part = part.strip()
            if not part:
                continue
            name, sep, size = part.partition(":")
            if not sep or not size.strip().isdigit():
                raise ValueError(
                    f"bad mesh spec {mesh!r}; expected 'data:8' or "
                    f"'data:4,model:2'")
            out.append((name.strip(), int(size)))
        return _drop_unit_axes(tuple(out))
    if isinstance(mesh, Mapping):
        return _drop_unit_axes(
            tuple((str(k), int(v)) for k, v in mesh.items()))
    shape = getattr(mesh, "shape", None)
    if isinstance(shape, Mapping):        # jax.sharding.Mesh
        return _drop_unit_axes(
            tuple((str(k), int(v)) for k, v in shape.items()))
    return _drop_unit_axes(tuple((str(k), int(v)) for k, v in mesh))


def mesh_data_size(axes: tuple) -> int:
    d = 1
    for name, size in axes:
        if name in DATA_AXIS_NAMES:
            d *= int(size)
    return d


def mesh_data_axes(axes: tuple) -> tuple:
    """The data-parallel (batch-sharded) axes of a normalized mesh."""
    return tuple((n, s) for n, s in axes if n in DATA_AXIS_NAMES)


def mesh_model_axes(axes: tuple) -> tuple:
    """The model-parallel (tensor-sharded) axes of a normalized mesh."""
    return tuple((n, s) for n, s in axes if n not in DATA_AXIS_NAMES)


def mesh_model_size(axes: tuple) -> int:
    m = 1
    for _, size in mesh_model_axes(axes):
        m *= int(size)
    return m


def format_mesh(axes: tuple) -> str:
    return ("x".join(f"{n}={s}" for n, s in axes)) if axes else "(no mesh)"


def _ring(d: int) -> float:
    """Per-device bytes-on-the-wire multiplier of a ring all-reduce."""
    return 2.0 * (d - 1) / d if d > 1 else 0.0


# ---------------------------------------------------------------------------
# Scalar cost models (kept as the stable, unit-tested crossover formulas)


def dense_norm_method(T: int, Di: int, Do: int, B: int,
                      mem_budget: int = STREAM_MEM_BUDGET) -> str:
    if T == 1:
        return "rank1"
    gram_flops = 2 * T * T * (Di + Do)
    stream_flops = 4 * T * Di * Do
    stream_mem = B * Di * Do * BYTES
    if stream_flops < gram_flops and stream_mem <= mem_budget:
        return "stream"
    return "gram"


def seg_norm_method(S: int, Di: int, Do: int, B: int, G: int,
                    mem_budget: int = STREAM_MEM_BUDGET) -> str:
    """MoE expert slots: gram is O(G·S²·(Di+Do+B)), stream is
    O(G·B·Di·Do) FLOPs with (B·Di·Do) scratch per expert-group step."""
    gram_flops = G * S * S * (Di + Do + B)
    stream_flops = G * B * Di * Do
    stream_mem = B * Di * Do * BYTES
    if stream_flops < gram_flops and stream_mem <= mem_budget:
        return "stream"
    return "gram"


def conv_norm_method(T: int, C: int, D: int, K: int, B: int, groups: int = 1,
                     mem_budget: int = STREAM_MEM_BUDGET) -> str:
    """Conv ghost-norm (im2col Gram over T output positions with per-group
    features F = (C/g)·K) vs materializing the per-example weight gradient
    (the paper's Algorithm 2).  Early layers (large spatial T, few
    channels) want ``pe``; late layers (tiny T, wide channels) want
    ``ghost`` — the per-layer mix of Bu et al. (2022).

    ``T`` = output positions, ``K`` = prod(kernel spatial dims).
    """
    g = max(groups, 1)
    F, Dg = (C // g) * K, D // g
    ghost_flops = 2 * T * T * (F + Dg) * g
    pe_flops = 4 * T * F * Dg * g
    pe_mem = B * D * (C // g) * K * BYTES
    if pe_flops < ghost_flops and pe_mem <= mem_budget:
        return "pe"
    return "ghost"


EMBED_PE_BUDGET = 32 << 20  # materialize embed pe grads below this


def embed_norm_method(T: int, D: int, B: int | None = None,
                      vocab: int | None = None,
                      pe_budget: int = EMBED_PE_BUDGET) -> str:
    """segsum is O(T·logT + T·D); the same-token-masked Gram is O(T²·D);
    materializing the (B, V, D) per-example grad (``pe``) costs O(B·V·D)
    but its sort-free scatter beats segsum's lane-serial argsort whenever
    the table is small — and the materialized grads make the sum phase
    free (stash).  ``pe`` is picked only under a hard memory bound."""
    if B is not None and vocab is not None \
            and B * vocab * D * BYTES <= pe_budget:
        return "pe"
    return "gram" if T <= 32 else "segsum"


# ---------------------------------------------------------------------------
# Plan structures


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Per-tap decision + cost estimates.

    All estimates are *per device*: with no mesh that is the whole batch;
    under a mesh the batch-linear FLOPs and scratch are for this device's
    batch shard, and ``coll_bytes`` is this device's share of the
    collective traffic the chosen realization induces per step."""

    name: str
    kind: str
    norm_method: str          # gram|stream|rank1|pallas|pe|segsum|...
    stash: bool               # norm phase materializes per-example grads
    norm_flops: float
    contrib_flops: float
    wgrad_flops: float        # this layer's share of a weighted backward
    stash_bytes: float = 0.0  # size of the (B, *param) grads if stashed
    fallback_norm: str = ""   # best no-stash method (cumulative demotion)
    param_bytes: float = 0.0  # parameter bytes (grad-sync unit, per shard)
    coll_bytes: float = 0.0   # predicted collective bytes per step
    ex_per_dev: float = 0.0   # examples on one device's batch shard
    fused: bool = False       # stale mode: single-pass gram_norm_fused
    model_shards: int = 1     # tensor-parallel degree this layer splits over
    coll_bytes_by_axis: tuple = ()  # (("data", bytes), ...) per mesh axis


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """One parameter (pytree path); >1 member means shared/tied taps."""

    path: tuple
    members: tuple                 # tap names
    norm_mode: str                 # single | tied | group_pe
    sum_method: str                # stash | contrib | backward


PLAN_FORMAT_VERSION = 7   # v7: block-level "attn" realization (ghost/pe)

_META_FIELDS = ("kind", "path", "param_key", "bias_key", "w_transposed",
                "segmented", "scanned", "shared", "static")


def _retuple(x):
    """JSON arrays back to tuples (paths, kernel shapes, strides...)."""
    if isinstance(x, list):
        return tuple(_retuple(v) for v in x)
    if isinstance(x, dict):
        return {k: _retuple(v) for k, v in x.items()}
    return x


def _jsonable(x):
    if isinstance(x, tuple):
        return [_jsonable(v) for v in x]
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    return x


def _make_taps_from(tap_shapes: dict) -> Callable:
    def make_taps():
        return {n: jnp.zeros(s.shape, s.dtype) for n, s in tap_shapes.items()}
    return make_taps


@dataclasses.dataclass(frozen=True, eq=False)
class ExecPlan:
    """The per-layer execution plan — a first-class, frozen value.

    Inspect with :meth:`explain` (per-layer table of chosen norm/sum
    realizations with predicted FLOPs/bytes), serialize with
    :meth:`to_json` / :meth:`from_json` for cross-process caching keyed on
    :attr:`fingerprint` (model + batch/param shapes + planner knobs).  A
    deserialized plan executes without re-probing: tap zeros are rebuilt
    from :attr:`tap_shapes` and layer metadata is re-validated against the
    live capture trace (so a stale plan fails loudly, not wrongly).
    """

    groups: tuple
    layers: dict                   # name -> LayerPlan
    metas: dict                    # name -> LayerMeta
    make_taps: Callable
    needs_backward: bool
    total_norm_flops: float
    total_contrib_flops: float
    tap_shapes: dict = dataclasses.field(default_factory=dict)
    capture_bytes: float = 0.0     # captures + tap cotangents, per device
    fingerprint: str = ""
    mesh: tuple = ()               # (("data", 8), ...) this plan targets
    batch_sig: tuple = ()          # batch shape signature the plan was built on
    total_coll_bytes: float = 0.0  # per-device collective bytes per step
    total_coll_bytes_by_axis: tuple = ()  # (("data", bytes), ...) breakdown
    clip_mode: str = "flat"        # flat | per_layer | stale (coefficient flow)
    calibration: str = ""          # Calibration digest priced under ("" analytic)
    _anchor: Any = None            # pins apply_fn identity while cached

    def describe(self) -> str:
        lines = []
        for g in self.groups:
            for n in g.members:
                lp = self.layers[n]
                lines.append(f"{n}: kind={lp.kind} norm={lp.norm_method} "
                             f"sum={g.sum_method}")
        return "\n".join(lines)

    # -- inspection --------------------------------------------------------

    def sum_methods(self) -> dict:
        return {n: g.sum_method for g in self.groups for n in g.members}

    def peak_stash_bytes(self) -> float:
        """Stashes coexist from the norm phase to the sum phase; a group's
        members share one parameter, so it stashes one (B, *param) tree."""
        return sum(max(self.layers[n].stash_bytes for n in g.members)
                   for g in self.groups if g.sum_method == "stash")

    def explain(self) -> str:
        """Per-layer table of the chosen realizations and predicted costs
        (per device; the ``coll MB`` column is the predicted collective
        traffic the realization induces on the plan's mesh)."""
        sums = self.sum_methods()
        header = (f"{'layer':<28} {'kind':<10} {'norm':<8} {'sum':<9} "
                  f"{'norm MF':>9} {'sum MF':>9} {'stash MB':>9} "
                  f"{'coll MB':>9}")
        lines = [header, "-" * len(header)]
        for n, lp in self.layers.items():
            stash_mb = lp.stash_bytes / 2**20 if lp.stash else 0.0
            sum_m = "fused" if lp.fused else sums.get(n, "?")
            lines.append(
                f"{n:<28} {lp.kind:<10} {lp.norm_method:<8} "
                f"{sum_m:<9} {lp.norm_flops / 1e6:>9.2f} "
                f"{lp.contrib_flops / 1e6:>9.2f} {stash_mb:>9.2f} "
                f"{lp.coll_bytes / 2**20:>9.2f}")
        passes = ("2 fwd + 2 bwd (shared weighted backward)"
                  if self.needs_backward else "1 fwd + 1 bwd")
        n_fused = sum(lp.fused for lp in self.layers.values())
        lines.append("-" * len(header))
        lines.append(
            f"steady-state passes: {passes}; total norm "
            f"{self.total_norm_flops / 1e6:.2f} MF, contrib "
            f"{self.total_contrib_flops / 1e6:.2f} MF; captures "
            f"{self.capture_bytes / 2**20:.2f} MB, peak stash "
            f"{self.peak_stash_bytes() / 2**20:.2f} MB")
        lines.append(
            f"clipping mode: {self.clip_mode}"
            + (f" ({n_fused} fused single-pass norm+contrib layer"
               f"{'s' if n_fused != 1 else ''})" if n_fused else ""))
        per_axis = ("; per axis: " + ", ".join(
            f"{a}={b / 2**20:.2f} MB"
            for a, b in self.total_coll_bytes_by_axis)
            if self.total_coll_bytes_by_axis else "")
        lines.append(
            f"mesh: {format_mesh(self.mesh)}; predicted collectives "
            f"{self.total_coll_bytes / 2**20:.2f} MB/step/device"
            + per_axis)
        lines.append(
            f"cost constants: measured calibration {self.calibration}"
            if self.calibration else
            "cost constants: analytic fallback (no calibration)")
        if self.fingerprint:
            lines.append(f"fingerprint: {self.fingerprint}")
        return "\n".join(lines)

    # -- serialization -----------------------------------------------------

    def to_payload(self) -> dict:
        metas = {n: {f: _jsonable(getattr(m, f)) for f in _META_FIELDS}
                 for n, m in self.metas.items()}
        return {
            "format": PLAN_FORMAT_VERSION,
            "fingerprint": self.fingerprint,
            "mesh": _jsonable(self.mesh),
            "batch_sig": _jsonable(self.batch_sig),
            "clip_mode": self.clip_mode,
            "needs_backward": self.needs_backward,
            "total_norm_flops": self.total_norm_flops,
            "total_contrib_flops": self.total_contrib_flops,
            "total_coll_bytes": self.total_coll_bytes,
            "total_coll_bytes_by_axis":
                _jsonable(self.total_coll_bytes_by_axis),
            "calibration": self.calibration,
            "capture_bytes": self.capture_bytes,
            "layers": {n: _jsonable(dataclasses.asdict(lp))
                       for n, lp in self.layers.items()},
            "groups": [{"path": list(g.path), "members": list(g.members),
                        "norm_mode": g.norm_mode,
                        "sum_method": g.sum_method} for g in self.groups],
            "metas": metas,
            "tap_shapes": {n: {"shape": list(s.shape), "dtype": str(s.dtype)}
                           for n, s in self.tap_shapes.items()},
        }

    def to_json(self, **json_kw) -> str:
        return json.dumps(self.to_payload(), **json_kw)

    @classmethod
    def from_payload(cls, p: dict) -> "ExecPlan":
        if p.get("format") != PLAN_FORMAT_VERSION:
            raise ValueError(
                f"unsupported plan format {p.get('format')!r} "
                f"(this build reads {PLAN_FORMAT_VERSION})")
        layers = {
            n: LayerPlan(**{**d, "coll_bytes_by_axis":
                            _retuple(d.get("coll_bytes_by_axis", []))})
            for n, d in p["layers"].items()}
        groups = tuple(
            GroupPlan(tuple(g["path"]), tuple(g["members"]),
                      g["norm_mode"], g["sum_method"]) for g in p["groups"])
        metas = {}
        for n, d in p["metas"].items():
            metas[n] = LayerMeta(
                kind=d["kind"], path=tuple(d["path"]),
                param_key=d["param_key"], bias_key=d["bias_key"],
                w_transposed=d["w_transposed"], segmented=d["segmented"],
                scanned=d["scanned"], shared=d["shared"],
                static=_retuple(d["static"]))
        tap_shapes = {
            n: jax.ShapeDtypeStruct(tuple(s["shape"]), s["dtype"])
            for n, s in p["tap_shapes"].items()}
        return cls(groups=groups, layers=layers, metas=metas,
                   make_taps=_make_taps_from(tap_shapes),
                   needs_backward=p["needs_backward"],
                   total_norm_flops=p["total_norm_flops"],
                   total_contrib_flops=p["total_contrib_flops"],
                   tap_shapes=tap_shapes,
                   capture_bytes=p["capture_bytes"],
                   fingerprint=p["fingerprint"],
                   mesh=_retuple(p.get("mesh", [])),
                   batch_sig=_retuple(p.get("batch_sig", [])),
                   total_coll_bytes=p.get("total_coll_bytes", 0.0),
                   total_coll_bytes_by_axis=_retuple(
                       p.get("total_coll_bytes_by_axis", [])),
                   clip_mode=p.get("clip_mode", "flat"),
                   calibration=p.get("calibration", ""))

    @classmethod
    def from_json(cls, s: str) -> "ExecPlan":
        return cls.from_payload(json.loads(s))

    def __eq__(self, other) -> bool:
        """Semantic equality: the serialized payload (closures and live
        ``fn`` references excluded), so ``from_json(to_json(p)) == p``."""
        if not isinstance(other, ExecPlan):
            return NotImplemented
        return self.to_payload() == other.to_payload()


# ---------------------------------------------------------------------------
# Per-layer geometry + planning


def _prod(xs) -> int:
    return int(math.prod(int(x) for x in xs)) if xs else 1


def _tree_elems(tree) -> int:
    return sum(_prod(leaf.shape) for leaf in jax.tree.leaves(tree))


def _plan_layer(name: str, meta: LayerMeta, cap_sh: dict, dy_sh,
                *, norm_method: str, embed_method: str, conv_norm: str,
                mem_budget: int, vocab: int | None = None,
                params_sub=None, mesh: tuple = (),
                clip_mode: str = "flat",
                clip_fused: bool = True,
                cc: CostConstants = ANALYTIC_CONSTANTS) -> LayerPlan:
    """Costs for one tap.  Stacked (scanned) applications multiply the
    per-application cost; shared stacked dense/scale layers fold the stack
    into the sequence axis first (matching kinds.apply_kind semantics).

    The auto choice minimizes the *joint* norm + sum cost: a norm that
    materializes per-example grads makes the sum phase a free (B,)-weighted
    reduction over the stash, so ``stream``/``pe`` is charged once while
    ``gram``/``ghost`` is charged norm + contraction.

    Under a mesh all estimates are per device (batch-linear terms use the
    per-device batch shard; the memory budget is per-device HBM), and the
    candidates additionally pay their collective traffic in
    FLOP-equivalents: stash candidates put per-example grads on the wire,
    non-materializing norms all-reduce ``B`` scalars."""
    k = meta.scanned
    dy_shape = tuple(dy_sh.shape)
    stack = _prod(dy_shape[:k])
    app_dy = dy_shape[k:]
    d = mesh_data_size(mesh)
    ring = _ring(d)
    daxes = mesh_data_axes(mesh)
    maxes = mesh_model_axes(mesh)
    msize = mesh_model_size(mesh)

    def _shard(B: int) -> int:
        return max(1, -(-int(B) // d))

    def _data_wire(nbytes: float) -> float:
        # Bytes crossing the data-parallel ring(s), priced on the axis
        # they actually cross: a hierarchical all-reduce moves ring(s)
        # bytes per axis of size s, each at that axis's measured price.
        return sum(cc.coll_price(a) * nbytes * _ring(s) for a, s in daxes)

    def _model_wire(nbytes: float) -> float:
        # Bytes psum'd over the model (tensor-parallel) axes — the
        # partial-Gram / partial-norm reduction of tensor-sharded layers.
        return sum(cc.coll_price(a) * nbytes * _ring(s) for a, s in maxes)

    def _scal_cost(B: int, model_sharded: bool = False) -> float:
        # all-reduce of the per-example scalar norms: (B,) float32.
        # Per-layer clipping drops the *data*-axis reduction: a layer's
        # coefficient depends only on its own norm, which lives on the
        # shard holding the example.  A tensor-sharded layer still pays
        # the model-axis psum — its per-example norm is assembled from
        # partial Grams that live on every model shard.
        w = 0.0 if clip_mode == "per_layer" else _data_wire(B * BYTES)
        if model_sharded:
            w += _model_wire(B * BYTES)
        return w

    def _fused_credit(read_bytes: float, cand_flops: float) -> float:
        # Stale coefficients are known entering the pass, so the Gram
        # norm and the weighted contribution share one HBM read of the
        # captures (gram_norm_fused) instead of two passes.  The credit
        # is capped at a sliver of the candidate's own FLOPs so it
        # breaks near-ties toward fusing but can never flip a layer
        # whose materializing path holds a real compute advantage (the
        # CPU/ref realization has no HBM read to save, and even on TPU
        # the read saving is second-order next to a FLOP gap).
        if clip_mode == "stale" and clip_fused:
            return min(cc.hbm_flops_per_byte * read_bytes,
                       0.05 * cand_flops)
        return 0.0

    def _move_cost(stash_bytes: float) -> float:
        # per-device per-example grads crossing the grad-sync ring; a
        # tensor-sharded layer's stash is its local param slice, so the
        # caller passes the already-divided per-shard bytes
        return _data_wire(stash_bytes)

    if meta.kind == "dense" and meta.segmented:
        x_shape = tuple(cap_sh["x"].shape)[k:]
        S, Di, Do = x_shape[-2], x_shape[-1], app_dy[-1]
        G = _prod(x_shape[:-2]) * stack
        B = meta.static["n_examples"]
        Bl = _shard(B)
        # Expert-sharded MoE layers place G/msh experts per model shard.
        msh = msize if msize > 1 and G % msize == 0 else 1
        Gl = G // msh
        m = (norm_method if norm_method not in ("auto", "pallas")
             else seg_norm_method(S, Di, Do, Bl, Gl, mem_budget))
        nf = (Gl * S * S * (Di + Do + Bl) if m == "gram"
              else Gl * Bl * Di * Do)
        cf = 2.0 * Gl * S * Di * Do
        return LayerPlan(name, "seg_dense", m, False, nf, cf, cf,
                         stash_bytes=Bl * Gl * Di * Do * BYTES,
                         param_bytes=Gl * Di * Do * BYTES, ex_per_dev=Bl,
                         model_shards=msh)

    if meta.kind == "dense":
        x_shape = tuple(cap_sh["x"].shape)[k:]
        B, Di, Do = x_shape[0], x_shape[-1], app_dy[-1]
        Bl = _shard(B)
        T = _prod(x_shape[1:-1])
        mult = stack
        if meta.shared and k:
            T, mult = T * stack, 1        # folded into the sequence axis
        # Tensor sharding over the model axes partitions the output
        # width: each device contracts its local Do/msh slice (the input
        # activations stay replicated), the per-example norm is the
        # model-axis psum of the partial Grams, and the stash/param
        # footprint is the local slice.
        msh = msize if msize > 1 and Do % msize == 0 else 1
        Dol = Do // msh
        cf = 2.0 * Bl * T * Di * Dol * mult
        pbytes = Di * Dol * BYTES * mult
        # Stashing keeps (B, *stack, Di, Do/msh) alive until the sum
        # phase; the un-stashed stream norm reduces one stacked layer at
        # a time (kinds.apply_kind's sequential loop), so it only needs
        # one layer's scratch but pays the contraction again in phase 2.
        mem_stash = Bl * Di * Dol * BYTES * mult
        mem_layer = Bl * Di * Dol * BYTES
        stash = False
        fallback = norm_method
        if norm_method == "auto":
            if T == 1:
                m = fallback = "rank1"
            else:
                per_ex = Bl * mult
                gram_flops = (2.0 * T * T * (Di + Dol)
                              + 2.0 * T * Di * Dol) * per_ex
                gram_total = (gram_flops + _scal_cost(B, msh > 1)
                              - _fused_credit(
                                  T * (Di + Dol) * BYTES * per_ex,
                                  gram_flops))
                stream_stash = (4.0 * T * Di * Dol * per_ex
                                + _move_cost(mem_stash))
                stream_again = (4.0 * T * Di * Dol
                                + 2.0 * T * Di * Dol) * per_ex \
                    + _scal_cost(B, msh > 1)
                fallback = ("stream" if stream_again < gram_total
                            and mem_layer <= mem_budget else "gram")
                if stream_stash < gram_total and mem_stash <= mem_budget:
                    m, stash = "stream", True
                else:
                    m = fallback
        else:
            m = norm_method
            stash = m == "stream" and mem_stash <= mem_budget
        if m == "rank1" and T != 1:
            m = fallback = "gram"
        nf = {"gram": 2.0 * T * T * (Di + Dol),
              "pallas": 2.0 * T * T * (Di + Dol),
              "stream": 4.0 * T * Di * Dol,
              "rank1": 2.0 * T * (Di + Dol)}[m] * Bl * mult
        return LayerPlan(name, "dense", m, stash, nf, cf, cf,
                         stash_bytes=mem_stash, fallback_norm=fallback,
                         param_bytes=pbytes, ex_per_dev=Bl,
                         model_shards=msh)

    if meta.kind == "conv":
        st = meta.static
        x_shape = tuple(cap_sh["x"].shape)[k:]
        B, C = x_shape[0], x_shape[1]
        Bl = _shard(B)
        D = app_dy[1]
        T = _prod(app_dy[2:])
        K = _prod(st["kernel_shape"][2:])
        g = max(st.get("groups", 1), 1)
        F, Dg = (C // g) * K, D // g
        # Tensor sharding partitions the output channels: each model
        # shard owns Dg/msh filters per group, contracts its local patch
        # slice for the ghost norm, and psums the partial per-example
        # norms over the model axes.
        msh = msize if msize > 1 and Dg % msize == 0 else 1
        Dgl = Dg // msh
        cf = 2.0 * Bl * T * F * Dgl * g * stack
        pbytes = (D // msh) * (C // g) * K * BYTES * stack
        mem_stash = Bl * (D // msh) * (C // g) * K * BYTES * stack
        mem_layer = Bl * (D // msh) * (C // g) * K * BYTES
        stash = False
        fallback = conv_norm
        if conv_norm == "auto":
            per_ex = Bl * stack
            ghost_flops = (2.0 * T * T * (F + Dgl)
                           + 2.0 * T * F * Dgl) * g * per_ex
            ghost_total = (ghost_flops + _scal_cost(B, msh > 1)
                           - _fused_credit(
                               T * (F + Dgl) * g * BYTES * per_ex,
                               ghost_flops))
            pe_stash = (4.0 * T * F * Dgl * g * per_ex
                        + _move_cost(mem_stash))
            pe_again = ((4.0 * T * F * Dgl + 2.0 * T * F * Dgl) * g * per_ex
                        + _scal_cost(B, msh > 1))
            fallback = ("pe" if pe_again < ghost_total
                        and mem_layer <= mem_budget else "ghost")
            if pe_stash < ghost_total and mem_stash <= mem_budget:
                m, stash = "pe", True
            else:
                m = fallback
        else:
            m = conv_norm
            stash = m == "pe" and mem_stash <= mem_budget
        nf = (2.0 * Bl * T * T * (F + Dgl) * g if m == "ghost"
              else 4.0 * Bl * T * F * Dgl * g) * stack
        return LayerPlan(name, "conv", m, stash, nf, cf, cf,
                         stash_bytes=mem_stash, fallback_norm=fallback,
                         param_bytes=pbytes, ex_per_dev=Bl,
                         model_shards=msh)

    if meta.kind == "embed":
        ids_shape = tuple(cap_sh["ids"].shape)[k:]
        B = ids_shape[0]
        Bl = _shard(B)
        T = _prod(ids_shape[1:])
        D = app_dy[-1]
        V = vocab or T
        # A vocab-sharded table keeps V/msh rows per model shard; the
        # same-token Gram and segsum norms see only locally-owned rows,
        # so their partial norms psum over the model axes.
        msh = msize if msize > 1 and V % msize == 0 else 1
        Vl = V // msh
        pbytes = Vl * D * BYTES * stack
        stash_bytes = Bl * Vl * D * BYTES * stack
        seg_f = (T * max(math.log2(max(T, 2)), 1.0) + 2.0 * T * D)
        costs = {"pe": Bl * (T * D + Vl * D) * stack
                 + _move_cost(stash_bytes),
                 "gram": 2.0 * Bl * T * T * D * stack
                 + _scal_cost(B, msh > 1),
                 "segsum": Bl * seg_f * stack + _scal_cost(B, msh > 1)}
        if embed_method != "auto":
            m = embed_method
        elif not mesh:
            # stack multiplies the stashed (B, V, D) scratch for the budget
            m = embed_norm_method(T, D, B * stack, vocab)
        else:
            # Mesh-aware: the stash's ring traffic competes with the
            # scalar all-reduce of the ghost realizations.
            m = min(costs, key=costs.get)
            if m == "pe" and stash_bytes > EMBED_PE_BUDGET:
                m = "gram" if T <= 32 else "segsum"
        nf = {"gram": 2.0 * Bl * T * T * D,
              "pe": Bl * (T * D + Vl * D),
              "segsum": Bl * seg_f}[m] * stack
        cf = 2.0 * Bl * T * D * stack
        fb = (m if m != "pe" else ("gram" if T <= 32 else "segsum"))
        return LayerPlan(name, "embed", m, m == "pe", nf, cf, cf,
                         stash_bytes=stash_bytes, fallback_norm=fb,
                         param_bytes=pbytes, ex_per_dev=Bl,
                         model_shards=msh)

    if meta.kind == "scale":
        B = app_dy[0] if app_dy else 1
        Bl = _shard(B)
        n = 2.0 * Bl * (_prod(app_dy) // max(B, 1)) * stack
        return LayerPlan(name, "scale", "pe", True, n, n, n,
                         stash_bytes=Bl * app_dy[-1] * BYTES * stack
                         if app_dy else 0.0,
                         param_bytes=(app_dy[-1] * BYTES * stack
                                      if app_dy else 0.0),
                         ex_per_dev=Bl)

    if meta.kind == "attn":
        # Whole attention block tapped as a unit (gqa/mla dp_attn): the
        # norm phase recomputes the block forward+backward once (the
        # layer-local tap-differentiation in kinds._attn_parts costs one
        # fwd + one bwd of the block, ≈ 3x the projection matmuls plus
        # the T² score work) and then realizes each projection's norm:
        # "ghost" runs the inner Gram contractions, "pe" materializes and
        # stashes per-projection per-example grads so the sum phase is a
        # free weighted reduction over the stash.
        x_shape = tuple(cap_sh["x"].shape)[k:]
        B = x_shape[0]
        Bl = _shard(B)
        T = _prod(x_shape[1:-1])
        proj = tuple(meta.static["proj_dims"])
        qk = meta.static.get("qk_flops", 0)
        per_ex = Bl * stack
        proj_flops = sum(2.0 * T * Di * Do for Di, Do in proj)
        recompute = 3.0 * (proj_flops + 4.0 * T * T * qk) * per_ex
        gram = sum(2.0 * T * T * (Di + Do) for Di, Do in proj) * per_ex
        outer = 2.0 * proj_flops * per_ex
        psize = sum(Di * Do for Di, Do in proj)
        mem_stash = Bl * psize * BYTES * stack
        pbytes = psize * BYTES * stack
        ghost_total = recompute + gram + _scal_cost(B)
        pe_stash = recompute + outer + _move_cost(mem_stash)
        m_req = norm_method if norm_method in ("ghost", "pe") else "auto"
        stash = False
        if m_req == "auto":
            if pe_stash < ghost_total and mem_stash <= mem_budget:
                m, stash = "pe", True
            else:
                m = "ghost"
        else:
            m = m_req
            stash = m == "pe" and mem_stash <= mem_budget
        nf = recompute + (outer if m == "pe" else gram)
        cf = recompute + proj_flops * per_ex
        return LayerPlan(name, "attn", m, stash, nf, cf,
                         proj_flops * per_ex,
                         stash_bytes=mem_stash, fallback_norm="ghost",
                         param_bytes=pbytes, ex_per_dev=Bl)

    # local_vjp: a layer-local VJP under vmap.  The norm phase
    # materializes per-example grads and stashes them when the (B, *param)
    # scratch fits the budget, making the sum free.  When the stash is
    # vetoed, the standalone contraction replays the per-example VJP —
    # charged LOCAL_VJP_CONTRIB_PENALTY over the batched backward's share
    # (vmap of a scan-based layer lowers far worse than one batched
    # backward) — which is what can tip the plan into the shared
    # weighted backward.
    B = app_dy[0] if app_dy else 1
    Bl = _shard(B)
    n = 2.0 * Bl * (_prod(app_dy) // max(B, 1)) * stack
    # params_sub at meta.path already carries the stacked axis in its leaf
    # shapes for scanned layers, so B * elems is the full stash size.
    psize = _tree_elems(params_sub) if params_sub is not None else 0
    stash_mem = Bl * psize * BYTES
    stash = psize == 0 or stash_mem <= mem_budget
    return LayerPlan(name, meta.kind, "pe", stash, n,
                     LOCAL_VJP_CONTRIB_PENALTY * n, n,
                     stash_bytes=stash_mem, param_bytes=psize * BYTES,
                     ex_per_dev=Bl)


def _vocab_of(meta: LayerMeta, params) -> int | None:
    if params is None:
        return meta.static.get("vocab")
    try:
        leaf = get_subtree(params, meta.path)[meta.param_key]
        return int(leaf.shape[-2])
    except (KeyError, TypeError, IndexError):
        return None


_OVERRIDE_METHODS = {
    "dense": {"auto", "gram", "stream", "rank1", "pallas"},
    "embed": {"auto", "segsum", "gram", "pe"},
    "conv": {"auto", "ghost", "pe", "pallas"},
    "attn": {"auto", "ghost", "pe"},
}


def normalize_overrides(overrides) -> tuple:
    """Per-layer overrides as an ordered, hashable tuple of (pattern,
    method) pairs.  Patterns are fnmatch globs over tap names (``"conv1"``,
    ``"blocks/*"``); the first match wins, in the order given (dict
    insertion order is preserved)."""
    if not overrides:
        return ()
    if isinstance(overrides, Mapping):
        overrides = overrides.items()
    return tuple((str(p), str(m)) for p, m in overrides)


def _override_for(name: str, kind: str, overrides: tuple) -> str | None:
    """First matching override for this layer.  Kinds with no override
    vocabulary (scale, local_vjp) ignore matches — a block-level glob like
    ``"blocks/*"`` inevitably sweeps up their taps — but a method that is
    wrong for an overridable kind is a hard error."""
    valid = _OVERRIDE_METHODS.get(kind)
    if valid is None:
        return None
    for pat, m in overrides:
        if fnmatchcase(name, pat):
            if m not in valid:
                raise ValueError(
                    f"per-layer override {pat!r}={m!r} invalid for {kind} "
                    f"layer {name!r}; choose from {sorted(valid)}")
            return m
    return None


def _nbytes(sds) -> float:
    return float(_prod(sds.shape)) * jnp.dtype(sds.dtype).itemsize


def plan_execution(metas: dict, cap_shapes: dict, tap_shapes: dict,
                   make_taps: Callable, params=None, *,
                   norm_method: str = "auto", embed_method: str = "auto",
                   conv_norm: str = "auto",
                   mem_budget: int = STREAM_MEM_BUDGET,
                   overrides=None, mesh=None, clip_mode: str = "flat",
                   clip_fused: bool = True, calibration=None) -> ExecPlan:
    """Build the per-layer plan from probed shapes.

    Fixed ``norm_method`` / ``embed_method`` / ``conv_norm`` override the
    analytic choice uniformly (the planner still fills in cost estimates);
    ``overrides`` pins individual layers by tap-name glob and wins over
    both.  ``mesh`` (anything :func:`mesh_axes` accepts) switches every
    estimate to per-device and charges candidates their collective bytes.

    ``clip_mode`` shapes the plan around the coefficient flow of the
    executing :class:`~repro.core.clipping.ClipPolicy`: ``per_layer``
    drops the cross-layer norm all-reduce from the collective model and
    never selects the shared weighted backward (one backward cannot
    realize per-layer weights); ``stale`` also drops the backward (the
    known coefficients make every contraction direct) and, with
    ``clip_fused``, credits and marks Gram-realized dense/conv layers
    for the fused single-pass ``gram_norm_fused`` norm+contrib.

    ``calibration`` (a :class:`repro.calibrate.Calibration`, or ``None``
    for the registered one) supplies measured cost constants; every
    price below goes through the resolved :class:`CostConstants`, with
    :data:`ANALYTIC_CONSTANTS` as the documented fallback.
    """
    overrides = normalize_overrides(overrides)
    ms = mesh_axes(mesh)
    d = mesh_data_size(ms)
    cc = resolve_cost_constants(calibration, ms)
    layers: dict[str, LayerPlan] = {}
    by_path: dict[tuple, list] = {}
    for name, meta in metas.items():
        psub = None
        if params is not None and meta.kind == "local_vjp":
            try:
                psub = get_subtree(params, meta.path)
            except (KeyError, TypeError):
                psub = None
        ov = _override_for(name, meta.kind, overrides)
        layers[name] = _plan_layer(
            name, meta, cap_shapes[name], tap_shapes[name],
            norm_method=ov or norm_method, embed_method=ov or embed_method,
            conv_norm=ov or conv_norm, mem_budget=mem_budget,
            vocab=_vocab_of(meta, params) if meta.kind == "embed" else None,
            params_sub=psub, mesh=ms, clip_mode=clip_mode,
            clip_fused=clip_fused, cc=cc)
        by_path.setdefault(meta.path, []).append(name)

    total_wgrad = sum(lp.wgrad_flops for lp in layers.values())
    # A weighted backward pays the forward + dx chain (the fixed factor)
    # AND computes every parameter's wgrad — including those of groups
    # that keep their stash/contraction, whose share is pure waste.  So
    # switching the candidate set to the backward only pays off when the
    # contractions it replaces exceed fixed + total_wgrad.  Under a mesh
    # it also psums the whole gradient a second time — sized by *unique*
    # parameters (taps sharing a path sync one gradient, not one each).
    unique_pbytes = sum(max(layers[n].param_bytes for n in names)
                        for names in by_path.values())
    backward_cost = (BACKWARD_FIXED_FACTOR + 1.0) * total_wgrad \
        + sum(cc.coll_price(a) * _ring(s) * unique_pbytes
              for a, s in mesh_data_axes(ms))

    groups: list[GroupPlan] = []
    for path, names in sorted(by_path.items()):
        if len(names) == 1:
            mode = "single"
            sum_method = "stash" if layers[names[0]].stash else "contrib"
        else:
            ks = sorted((metas[n].kind, metas[n].w_transposed) for n in names)
            mode = ("tied" if ks == [("dense", True), ("embed", False)]
                    and len(names) == 2 else "group_pe")
            if mode == "tied":
                n_e = next(n for n in names if metas[n].kind == "embed")
                if layers[n_e].norm_method == "pe":
                    # Small tied table: materializing the summed grad once
                    # beats segsum + Gram + the cross term, and stashes.
                    mode = "group_pe"
            # group_pe stashes the summed per-example grad during the norm
            # phase; tied contracts per member.
            sum_method = "stash" if mode == "group_pe" else "contrib"
        groups.append(GroupPlan(path, tuple(names), mode, sum_method))

    # All stashes live together from the norm phase to the sum phase, so
    # the budget is charged cumulatively; groups past it fall back to a
    # transient norm + phase-2 contraction (one layer's scratch at a time).
    running = 0.0
    for i, g in enumerate(groups):
        if g.sum_method != "stash":
            continue
        # members of a group share one parameter, so a group stashes one
        # (B, *param) tree: the largest member estimate, not the sum.
        gb = max(layers[n].stash_bytes for n in g.members)
        if running + gb > mem_budget:
            groups[i] = dataclasses.replace(g, sum_method="contrib")
            for n in g.members:
                lp = layers[n]
                # Re-decide the norm under no-stash economics: without the
                # free sum, the stash-optimal method may no longer win.
                fb = lp.fallback_norm or lp.norm_method
                layers[n] = dataclasses.replace(lp, stash=False,
                                                norm_method=fb)
        else:
            running += gb

    # Greedy backward set: groups whose contraction is dearer than their
    # wgrad share, kept only if the replaced contractions pay for the
    # whole extra backward.  Never under a non-flat clipping mode: one
    # weighted backward cannot realize per-layer coefficients, and stale
    # coefficients make every contraction direct (no phase barrier to
    # amortize a backward against).
    candidates: list[tuple[float, int]] = []
    if clip_mode == "flat":
        for i, g in enumerate(groups):
            if g.sum_method != "contrib":
                continue
            cost_c = sum(layers[n].contrib_flops for n in g.members)
            cost_b = sum(layers[n].wgrad_flops for n in g.members)
            if cost_c > cost_b:
                candidates.append((cost_c, i))

    saving = sum(s for s, _ in candidates)
    needs_backward = saving > backward_cost
    if needs_backward:
        for _, gi in candidates:
            groups[gi] = dataclasses.replace(groups[gi],
                                             sum_method="backward")

    # Stale coefficients are step-invariant inside the pass: mark the
    # Gram-realized dense/conv layers for the fused single-pass
    # norm+contrib (the execution routes them through gram_norm_fused).
    # Only single-tap groups fuse — tied/shared-path groups keep their
    # cross-term norm algebra — and only unscanned convs (the fused conv
    # path has no stacked-axis handling).
    if clip_mode == "stale" and clip_fused:
        single = {g.members[0] for g in groups if len(g.members) == 1}
        for name, lp in layers.items():
            if name not in single or lp.stash:
                continue
            fusable = (
                (lp.kind == "dense"
                 and lp.norm_method in ("gram", "pallas"))
                or (lp.kind == "conv" and metas[name].scanned == 0
                    and lp.norm_method in ("ghost", "pallas")))
            if fusable:
                layers[name] = dataclasses.replace(lp, fused=True)

    # Final per-layer collective prediction for the *chosen* realization,
    # broken out per mesh axis.  Data axes carry the norm phase (stash
    # movement vs the scalar all-reduce of the *global* (B,) norms, the
    # same term _scal_cost charged during selection) plus this layer's
    # share of its group's grad-sync psum — one sync per parameter, split
    # across the taps that share it, doubled for weighted-backward
    # groups.  Model axes carry the partial-norm psum of tensor-sharded
    # layers: their (B,) per-example norms are assembled from partial
    # Grams living on every model shard.
    if ms:
        for g in groups:
            group_pb = max(layers[n].param_bytes for n in g.members)
            sync_each = group_pb \
                * (2.0 if g.sum_method == "backward" else 1.0) \
                / len(g.members)
            for name in g.members:
                lp = layers[name]
                norm_bytes = (lp.stash_bytes if lp.stash
                              else lp.ex_per_dev * d * BYTES)
                by_axis = []
                for a, s in ms:
                    r = _ring(s)
                    if a in DATA_AXIS_NAMES:
                        b = (norm_bytes + sync_each) * r
                    else:
                        b = (lp.ex_per_dev * d * BYTES * r
                             if lp.model_shards > 1 else 0.0)
                    if b > 0.0:
                        by_axis.append((a, b))
                layers[name] = dataclasses.replace(
                    lp, coll_bytes=sum(b for _, b in by_axis),
                    coll_bytes_by_axis=tuple(by_axis))

    capture_bytes = 0.0
    for name in metas:
        capture_bytes += sum(_nbytes(leaf)
                             for leaf in jax.tree.leaves(cap_shapes[name]))
        ts = tap_shapes.get(name)
        if ts is not None:
            capture_bytes += 2.0 * _nbytes(ts)   # tap zeros + cotangent
    capture_bytes /= d   # captures are batch-sharded: per-device share

    axis_totals: dict[str, float] = {}
    for lp in layers.values():
        for a, b in lp.coll_bytes_by_axis:
            axis_totals[a] = axis_totals.get(a, 0.0) + b

    return ExecPlan(
        groups=tuple(groups), layers=layers, metas=metas,
        make_taps=make_taps, needs_backward=needs_backward,
        total_norm_flops=sum(lp.norm_flops for lp in layers.values()),
        total_contrib_flops=sum(lp.contrib_flops for lp in layers.values()),
        tap_shapes=dict(tap_shapes), capture_bytes=capture_bytes,
        mesh=ms, clip_mode=clip_mode, calibration=cc.calibration,
        total_coll_bytes=sum(lp.coll_bytes for lp in layers.values()),
        total_coll_bytes_by_axis=tuple(
            (a, axis_totals[a]) for a, _ in ms if a in axis_totals))


# ---------------------------------------------------------------------------
# Plan cache: (model identity, batch/param shapes, knobs) -> ExecPlan
#
# probe() re-traces the whole model; caching the probe + plan makes the
# steady-state auto path exactly one forward + one backward per step.


_PLAN_CACHE: "OrderedDict[tuple, ExecPlan]" = OrderedDict()


def _fn_ident(apply_fn) -> tuple:
    self = getattr(apply_fn, "__self__", None)
    if self is not None:
        return (id(self), getattr(apply_fn, "__name__", ""))
    return (id(apply_fn), "")


def _shape_sig(tree) -> tuple:
    return tuple(
        (jax.tree_util.keystr(kp), tuple(leaf.shape), str(leaf.dtype))
        for kp, leaf in jax.tree_util.tree_leaves_with_path(tree))


def plan_cache_key(apply_fn, params, batch, opts: tuple) -> tuple:
    return (_fn_ident(apply_fn), _shape_sig(batch), _shape_sig(params), opts)


@functools.lru_cache(maxsize=1)
def code_fingerprint() -> str:
    """Hash of the model/pipeline *sources* (``repro.models`` and
    ``repro.core`` package files).  Folded into every plan fingerprint so
    a plan-store entry produced by different code — a realization whose
    cost or semantics changed since the plan was serialized — fails the
    fingerprint check instead of silently executing under a stale plan."""
    import repro.core
    import repro.models
    h = hashlib.sha1()
    for pkg in (repro.core, repro.models):
        # __path__ (not __file__) also covers namespace packages.
        root = pathlib.Path(next(iter(pkg.__path__)))
        for path in sorted(root.rglob("*.py")):
            h.update(str(path.relative_to(root.parent)).encode())
            h.update(path.read_bytes())
    return h.hexdigest()[:12]


def model_fingerprint(apply_fn, params, batch, opts: tuple = ()) -> str:
    """Cross-process-stable plan identity: model qualname + batch/param
    shape signature + planner knobs + the model-code hash.  Unlike the
    in-process cache key this never uses ``id()``, so a plan exported
    from one process keys the same model in another — but only while the
    sources match (see :func:`code_fingerprint`)."""
    owner = getattr(apply_fn, "__self__", None)
    if owner is not None:
        ident = type(owner).__module__ + "." + type(owner).__qualname__
    else:
        ident = (getattr(apply_fn, "__module__", "") + "."
                 + getattr(apply_fn, "__qualname__", "<fn>"))
    payload = repr((ident, _shape_sig(batch), _shape_sig(params), opts,
                    code_fingerprint()))
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


def clear_plan_cache():
    _PLAN_CACHE.clear()


def plan_cache_info() -> dict:
    return {"size": len(_PLAN_CACHE), "store": len(_PLAN_STORE)}


# Cross-process plan store: fingerprint -> deserialized ExecPlan.  Filled by
# load_plan_store(); consulted by get_plan() before any probe, so a process
# that pre-loads its plans (serving, dry-run verification) never re-traces
# the model for planning.

_PLAN_STORE: dict[str, ExecPlan] = {}


def register_plan(plan: ExecPlan):
    if not plan.fingerprint:
        raise ValueError("plan has no fingerprint; build it via get_plan()")
    _PLAN_STORE[plan.fingerprint] = plan


def clear_plan_store():
    _PLAN_STORE.clear()


def save_plan_store(path: str, plans, extra: dict | None = None,
                    calibrations=None):
    """Write plans (+ optional extra metadata) as one JSON document.

    ``calibrations`` (iterable of ``repro.calibrate.Calibration``)
    persists measured constants alongside the plans; ``None``
    auto-collects every registered calibration whose digest some plan
    was priced under, so a store written after calibrated planning
    round-trips the constants it depends on."""
    plans = list(plans)
    if calibrations is None:
        try:
            from repro.calibrate import table as _ct
        except ImportError:       # pragma: no cover - calibrate ships
            calibrations = ()
        else:
            used = {p.calibration for p in plans if p.calibration}
            calibrations = [c for c in _ct.registered()
                            if c.digest() in used]
    doc = {"format": PLAN_FORMAT_VERSION,
           "plans": [p.to_payload() for p in plans]}
    calibrations = list(calibrations)
    if calibrations:
        doc["calibrations"] = [c.to_payload() for c in calibrations]
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def load_plan_store(path: str) -> int:
    """Load a plan JSON document into the store; returns the plan count.
    Calibrations persisted with the store are validated (named
    ``CalibrationError`` subclasses on tampered blobs — wrong rates are
    rejected here; hardware/mesh validation happens at use) and
    registered before the plans, so calibrated fingerprints resolve."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict) and doc.get("calibrations"):
        from repro.calibrate import table as _ct
        for cp in doc["calibrations"]:
            _ct.register(_ct.Calibration.from_payload(cp))
    plans = doc["plans"] if isinstance(doc, dict) else doc
    for p in plans:
        register_plan(ExecPlan.from_payload(p))
    return len(plans)


def _sig_summary(sig) -> str:
    return ", ".join(f"{k}{tuple(s)}:{dt}" for k, s, dt in sig) or "(empty)"


def check_plan_matches(plan: ExecPlan, *, fingerprint: str | None = None,
                       mesh=None, batch_sig=None, clip_mode: str | None = None,
                       calibration=None):
    """Validate a deserialized/injected plan against the live context,
    naming the offending field — mesh shape, batch shape, clipping mode,
    calibration, or fingerprint — so a stale plan fails loudly instead
    of executing a stale layout.  ``calibration`` may be a Calibration,
    its digest string, or ``""`` to assert analytic constants."""
    if calibration is not None:
        want = (calibration if isinstance(calibration, str)
                else calibration.digest())
        if plan.calibration != want:
            def _label(d):
                return f"measured constants {d}" if d else "analytic constants"
            raise ValueError(
                f"stale ExecPlan: calibration mismatch — plan "
                f"{plan.fingerprint or '<unfingerprinted>'} was priced "
                f"under {_label(plan.calibration)}, this process plans "
                f"under {_label(want)}; re-calibrate or re-plan")
    if clip_mode is not None and plan.clip_mode != clip_mode:
        raise ValueError(
            f"stale ExecPlan: clipping mode mismatch — plan "
            f"{plan.fingerprint or '<unfingerprinted>'} was built for "
            f"clipping mode {plan.clip_mode!r}, this process clips "
            f"{clip_mode!r}; re-plan for this policy")
    if mesh is not None:
        ms = mesh_axes(mesh)
        if tuple(plan.mesh) != ms:
            raise ValueError(
                f"stale ExecPlan: mesh shape mismatch — plan "
                f"{plan.fingerprint or '<unfingerprinted>'} was built for "
                f"mesh {format_mesh(tuple(plan.mesh))}, this process runs "
                f"{format_mesh(ms)}; re-plan for this topology")
    if batch_sig is not None and plan.batch_sig \
            and tuple(plan.batch_sig) != tuple(batch_sig):
        raise ValueError(
            f"stale ExecPlan: batch shape mismatch — plan "
            f"{plan.fingerprint or '<unfingerprinted>'} was built for "
            f"[{_sig_summary(plan.batch_sig)}], this step feeds "
            f"[{_sig_summary(batch_sig)}]")
    if fingerprint and plan.fingerprint and plan.fingerprint != fingerprint:
        raise ValueError(
            f"stale ExecPlan: fingerprint mismatch — plan "
            f"{plan.fingerprint} != expected {fingerprint} (model code, "
            f"param shapes, or planner knobs changed)")


def _opts_tuple(norm_method, embed_method, conv_norm, mem_budget,
                overrides, mesh, clip_mode="flat", clip_fused=True,
                calibration=None) -> tuple:
    ms = mesh_axes(mesh)
    calib = _resolve_calibration(calibration, ms)
    return (norm_method, embed_method, conv_norm, mem_budget,
            normalize_overrides(overrides), ms,
            (str(clip_mode), bool(clip_fused)),
            "" if calib is None else calib.digest())


def plan_fingerprint(apply_fn, params, batch, *, norm_method: str = "auto",
                     embed_method: str = "auto", conv_norm: str = "auto",
                     mem_budget: int = STREAM_MEM_BUDGET,
                     overrides=None, mesh=None, clip_mode: str = "flat",
                     clip_fused: bool = True, calibration=None) -> str:
    """The fingerprint :func:`get_plan` would key this request on — same
    knob normalization, no probe."""
    return model_fingerprint(
        apply_fn, params, batch,
        _opts_tuple(norm_method, embed_method, conv_norm, mem_budget,
                    overrides, mesh, clip_mode, clip_fused, calibration))


def get_plan(apply_fn, params, batch, *, norm_method: str = "auto",
             embed_method: str = "auto", conv_norm: str = "auto",
             mem_budget: int = STREAM_MEM_BUDGET,
             overrides=None, mesh=None, clip_mode: str = "flat",
             clip_fused: bool = True, calibration=None) -> ExecPlan:
    """Cached planner entry point.  The anchor reference pinned in the
    cached plan keeps ``id(apply_fn.__self__)`` stable for the entry's
    lifetime, so a recycled id can never alias a different model.  A
    fingerprint hit in the cross-process plan store short-circuits the
    probe entirely.  ``mesh`` participates in both the cache key and the
    fingerprint, so plans are topology-keyed; a store that holds this
    batch's plan for a *different* topology raises instead of silently
    re-planning over a stale layout.  ``calibration`` (explicit or the
    registered one for this mesh) participates the same way: its digest
    keys the cache and the fingerprint, so a plan priced under stale
    measured constants fails safe exactly like one built from stale
    code."""
    opts = _opts_tuple(norm_method, embed_method, conv_norm, mem_budget,
                       overrides, mesh, clip_mode, clip_fused, calibration)
    ov, ms = opts[4], opts[5]
    key = plan_cache_key(apply_fn, params, batch, opts)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_CACHE.move_to_end(key)
        return plan
    fp = model_fingerprint(apply_fn, params, batch, opts)
    plan = _PLAN_STORE.get(fp)
    if plan is None:
        sig = _shape_sig(batch)
        for cand in _PLAN_STORE.values():
            if tuple(cand.batch_sig) != sig or tuple(cand.mesh) == ms:
                continue
            # Only a store entry that is *this* request's plan on another
            # topology blocks planning: re-key the request under the
            # candidate's mesh and compare fingerprints, so an unrelated
            # model that merely shares the batch shape never trips this.
            cand_opts = _opts_tuple(
                norm_method, embed_method, conv_norm, mem_budget,
                overrides, tuple(cand.mesh), clip_mode, clip_fused,
                calibration)
            if cand.fingerprint == model_fingerprint(apply_fn, params,
                                                     batch, cand_opts):
                check_plan_matches(cand, mesh=ms)
        make_taps, metas, tap_shapes, cap_shapes = probe(
            apply_fn, params, batch, return_captures=True)
        plan = plan_execution(
            metas, cap_shapes, tap_shapes, make_taps, params,
            norm_method=norm_method, embed_method=embed_method,
            conv_norm=conv_norm, mem_budget=mem_budget, overrides=ov,
            mesh=ms, clip_mode=clip_mode, clip_fused=clip_fused,
            calibration=calibration)
        plan = dataclasses.replace(plan, fingerprint=fp, batch_sig=sig)
    object.__setattr__(plan, "_anchor", getattr(apply_fn, "__self__",
                                                apply_fn))
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > PLAN_CACHE_SIZE:
        _PLAN_CACHE.popitem(last=False)
    return plan


# ---------------------------------------------------------------------------
# Plan-driven microbatch scheduling


MICROBATCH_MEM_BUDGET = STREAM_MEM_BUDGET


def auto_microbatches(plan: ExecPlan, batch_size: int,
                      mem_budget: int | None = None) -> int:
    """Microbatch count from the plan's peak-memory estimates: the smallest
    divisor of ``batch_size`` whose per-microbatch peak (captures + tap
    cotangents + coexisting stashes — all linear in the leading batch axis)
    fits the budget.  Falls back to fully-sequential (``batch_size``) when
    even single-example microbatches estimate over budget."""
    budget = float(mem_budget or MICROBATCH_MEM_BUDGET)
    need = plan.capture_bytes + plan.peak_stash_bytes()
    B = max(int(batch_size), 1)
    m = 1
    while m < B and need / m > budget:
        m += 1
        while B % m and m < B:
            m += 1
    return m


# ---------------------------------------------------------------------------
# Predicted step cost: what the mispredict loop compares measurements
# against.  Priced in the same FLOP-equivalents the planner selects by,
# then converted to seconds through the calibrated (or analytic) rate.


def predicted_step_flops(plan: ExecPlan, cc: CostConstants | None = None
                         ) -> float:
    """Per-device FLOP-equivalents of one private step under this plan:
    forward + backward (≈ 2 wgrad shares) + wgrad + the plan's norm and
    contraction phases + the weighted second backward when taken + the
    wire price of the predicted collective bytes."""
    cc = cc or ANALYTIC_CONSTANTS
    total_wgrad = sum(lp.wgrad_flops for lp in plan.layers.values())
    flops = 3.0 * total_wgrad \
        + plan.total_norm_flops + plan.total_contrib_flops
    if plan.needs_backward:
        flops += (BACKWARD_FIXED_FACTOR + 1.0) * total_wgrad
    if plan.total_coll_bytes_by_axis:
        flops += sum(cc.coll_price(a) * b
                     for a, b in plan.total_coll_bytes_by_axis)
    else:
        flops += cc.collective_flops_per_byte * plan.total_coll_bytes
    return flops


def predicted_step_seconds(plan: ExecPlan, calibration=None) -> float:
    """Predicted wall-clock of one step: :func:`predicted_step_flops`
    under the plan's cost constants, over the (calibrated or analytic)
    FLOP rate."""
    cc = resolve_cost_constants(calibration, plan.mesh)
    return predicted_step_flops(plan, cc) / cc.flops_per_second


def planner_verdict(mesh_plan: ExecPlan, base_plan: ExecPlan,
                    calibration=None) -> str:
    """Judge a sharded plan against its unsharded counterpart with
    calibrated eyes: ``"sharded"`` when the mesh plan's predicted
    per-device step time beats the single-device plan's, else
    ``"unsharded"`` — the planner either fixes the plan or proves
    unsharded is right."""
    mesh_s = predicted_step_seconds(mesh_plan, calibration)
    base_s = predicted_step_seconds(base_plan, calibration)
    return "sharded" if mesh_s < base_s else "unsharded"
