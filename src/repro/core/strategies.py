"""Per-example gradient strategies.

The paper's three strategies plus the production extensions:

  * ``naive`` — batch-size-1 loop (``lax.map``); the semantics oracle.
  * ``multi`` — ``vmap(grad)``: JAX's native realization of "B model copies
    sharing parameters" (§2 of the paper, Goodfellow's GitHub suggestion).
  * ``crb``   — the paper's chain-rule-based method: one standard backward
    (via output taps), then per-layer reconstruction of per-example grads
    from (captured input, output cotangent) — outer products for dense
    layers, the grouped-convolution trick (Algorithms 1–2) for convs.
  * ``ghost`` — per-example grad *norms* without materialization (Gram
    trick) + a second, weighted backward pass.  O(1) extra memory.
  * ``bk``    — "book-keeping": like ghost, but the clipped sum is formed
    by weighted per-layer contractions from the captures already in hand —
    no second backward.
  * ``auto``  — the planned mixed pipeline: a cached per-layer execution
    plan (:mod:`repro.core.costmodel`) chooses, for every tapped layer,
    the cheapest exact norm realization (Gram ghost-norm — dense or
    im2col'd conv — streamed materialization, rank-1, segsum) and the sum
    phase (reuse grads the norm already materialized, book-keeping
    contraction, or a shared weighted backward when contractions would
    cost more than one extra backward).  The plan is keyed on (model,
    batch/param shapes), so steady-state training runs exactly **one**
    forward and **one** backward per step — no re-probe, no second
    backward — vs the ghost path's two of each.  The plan's cost table is
    also the seam future scaling work (sharding, microbatch schedules,
    new layer kinds) plugs into.

``apply_fn(params, batch, tapper) -> (B,) per-example losses`` is the only
contract a model must satisfy.  Execution counts (forwards / backwards /
probes) are tracked in :data:`repro.core.tapper.STATS`.

Sharded execution is the same code: strategies stay global-view pure
``jnp``, and the engine's declared in/out shardings (batch over the
data axes; params over ``model`` when tensor-sharded) make GSPMD insert
the collectives — per-example norm partials psum over ``model``, the
(B,)-scalar norms all-reduce over the data axes exactly once per layer
group, and the clipped+noised update all-reduces back to
data-replicated.  Nothing in this module branches on the mesh; the
planner (:mod:`repro.core.costmodel`) prices each of those collectives
on the axis it actually crosses.
"""
from __future__ import annotations

from collections import defaultdict

import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis.markers import tag
from repro.core import costmodel, kinds
from repro.core.tapper import (STATS, Tapper, capture_backward, get_subtree,
                               probe, set_subtree)

STRATEGIES = ("naive", "multi", "crb", "ghost", "bk", "auto")


# ---------------------------------------------------------------------------
# naive & multi


def _single_example_grad_fn(apply_fn, params):
    def gb(ex):
        ex1 = jax.tree.map(lambda a: a[None], ex)

        def loss(p):
            return apply_fn(p, ex1, Tapper())[0]

        return jax.value_and_grad(loss)(params)

    return gb


def naive_per_example_grads(apply_fn, params, batch):
    """Batch-size-1 loop — sequential, the paper's `naive`."""
    losses, grads = lax.map(_single_example_grad_fn(apply_fn, params), batch)
    return losses, grads


def multi_per_example_grads(apply_fn, params, batch):
    """vmap(grad) — the paper's `multi` (model copies sharing params)."""
    losses, grads = jax.vmap(_single_example_grad_fn(apply_fn, params))(batch)
    return losses, grads


# ---------------------------------------------------------------------------
# crb: capture + reconstruct


def _capture(apply_fn, params, batch):
    make_taps, metas, _ = probe(apply_fn, params, batch)
    losses, caps, dtaps = capture_backward(apply_fn, params, batch, make_taps())
    return losses, caps, dtaps, metas


def _accumulate_param_grads(acc: dict, path: tuple, sub: dict):
    """acc[path][key] += sub[key] (creating entries)."""
    slot = acc.setdefault(path, {})
    for k, v in sub.items():
        slot[k] = slot[k] + v if k in slot else v


def _grads_to_tree(acc: dict) -> dict:
    tree: dict = {}
    for path, sub in acc.items():
        for k, v in sub.items():
            tree = set_subtree(tree, path + (k,), v)
    return tree


def check_coverage(params, grads_tree) -> list[str]:
    """Param leaves with no per-example gradient contribution."""
    p_paths = {jax.tree_util.keystr(kp)
               for kp, _ in jax.tree_util.tree_leaves_with_path(params)}
    g_paths = {jax.tree_util.keystr(kp)
               for kp, _ in jax.tree_util.tree_leaves_with_path(grads_tree)}
    return sorted(p_paths - g_paths)


def crb_per_example_grads(apply_fn, params, batch, *, conv_impl: str = "fgc",
                          check: bool = True):
    """The paper's method: 1 backward + per-layer reconstruction."""
    losses, caps, dtaps, metas = _capture(apply_fn, params, batch)
    acc: dict = {}
    for name, meta in metas.items():
        pe = kinds.apply_kind(
            "pe_grad", meta, caps[name], dtaps[name],
            params_sub=get_subtree(params, meta.path), conv_impl=conv_impl)
        _accumulate_param_grads(acc, meta.path, pe)
    grads = _grads_to_tree(acc)
    if check:
        missing = check_coverage(params, grads)
        if missing:
            raise ValueError(f"params without per-example grads: {missing}")
    return losses, grads


# ---------------------------------------------------------------------------
# ghost norms (shared by ghost & bk)


def group_key_of(path: tuple) -> str:
    """The clip-budget key of a parameter group: its "/"-joined path."""
    return "/".join(str(p) for p in path)


def group_norms_from_captures(params, caps, dtaps, metas, *,
                              norm_method: str = "auto",
                              conv_impl: str = "fgc",
                              embed_method: str = "segsum",
                              conv_norm: str = "auto",
                              attn_norm: str = "auto"):
    """Per-parameter-group per-example squared grad norms, grouping taps
    that touch the same parameter (tied embeddings, shared blocks).

    Returns ``(group_keys, norms)`` with ``norms`` of shape (G, B), in
    sorted-path order — the same deterministic group order the planner's
    :class:`~repro.core.costmodel.ExecPlan` uses, so per-layer clip
    budgets resolved against either align."""
    by_param = defaultdict(list)
    for name, meta in metas.items():
        by_param[meta.path].append(name)

    # Segmented taps' leading axes are slots, not examples — the example
    # count comes from their static metadata (same rule as _batch_size).
    B = _batch_size(metas, dtaps)
    keys, norms = [], []

    def _tagged(n_sq, path, method="unplanned"):
        return tag(n_sq, kind="group_norm", group=group_key_of(path),
                   method=method, fused=False)

    for path, names in sorted(by_param.items()):
        keys.append(group_key_of(path))
        psub = get_subtree(params, path)
        if len(names) == 1:
            n = names[0]
            norms.append(_tagged(kinds.apply_kind(
                "norm_sq", metas[n], caps[n], dtaps[n], params_sub=psub,
                norm_method=norm_method, conv_impl=conv_impl,
                embed_method=embed_method, conv_norm=conv_norm,
                attn_norm=attn_norm), path))
            continue
        ks = sorted((metas[n].kind, metas[n].w_transposed) for n in names)
        if ks == [("dense", True), ("embed", False)] and len(names) == 2:
            # Tied embedding + LM head: per-tap norms plus the cross term.
            n_e = next(n for n in names if metas[n].kind == "embed")
            n_d = next(n for n in names if metas[n].kind == "dense")
            n_g = kinds.apply_kind(
                "norm_sq", metas[n_e], caps[n_e], dtaps[n_e], params_sub=psub,
                embed_method=embed_method)
            n_g = n_g + kinds.apply_kind(
                "norm_sq", metas[n_d], caps[n_d], dtaps[n_d], params_sub=psub,
                norm_method=norm_method)
            norms.append(_tagged(n_g + kinds.tied_embed_head_cross(
                caps[n_e], dtaps[n_e], caps[n_d], dtaps[n_d]), path, "tied"))
            continue
        # Generic exact fallback: materialize the summed per-example grad.
        pe_sum: dict = {}
        for n in names:
            pe = kinds.apply_kind("pe_grad", metas[n], caps[n], dtaps[n],
                                  params_sub=psub, conv_impl=conv_impl)
            for k, v in pe.items():
                pe_sum[k] = pe_sum[k] + v if k in pe_sum else v
        norms.append(_tagged(kinds._sumsq(pe_sum), path, "pe"))
    if not norms:
        raise ValueError("no tapped layers")
    return tuple(keys), jnp.stack(norms)


def ghost_norms_from_captures(params, caps, dtaps, metas, **kw):
    """Per-example squared norms of the *full* gradient (the flat-mode
    total): sum of the per-group norms."""
    _, norms = group_norms_from_captures(params, caps, dtaps, metas, **kw)
    return jnp.sum(norms, axis=0)


def ghost_norms(apply_fn, params, batch, **kw):
    losses, caps, dtaps, metas = _capture(apply_fn, params, batch)
    norms_sq = ghost_norms_from_captures(params, caps, dtaps, metas, **kw)
    return losses, norms_sq, (caps, dtaps, metas)


# ---------------------------------------------------------------------------
# clipped gradient sums (the DP-SGD core)


def clip_coefficients(norms_sq, l2_clip, eps: float = 1e-12, *,
                      mode: str = "flat"):
    norms = jnp.sqrt(norms_sq + eps)
    coef = jnp.minimum(1.0, l2_clip / norms)
    # Structural marker the static verifier keys on: downstream of this
    # tag, multiplying by ``coef`` IS the clip contraction.  A mutant
    # that replaces the coefficients wholesale loses the tag — itself a
    # finding.  ``mode`` records which policy produced them ("stale"
    # when fed lagged norms).
    params = {"kind": "clip_coef", "mode": mode}
    try:
        params["l2_clip"] = float(l2_clip)
    except TypeError:  # traced bound: still tag, just without the value
        pass
    return tag(coef, **params)


def per_layer_clip_coefficients(group_norms_sq, budgets, eps: float = 1e-12):
    """(G, B) coefficients: each group clipped against its own budget."""
    norms = jnp.sqrt(group_norms_sq + eps)
    return tag(jnp.minimum(1.0, budgets[:, None] / norms),
               kind="clip_coef", mode="per_layer")


def _pe_tree_norms_sq(pe_grads):
    return kinds._sumsq(pe_grads)


def _flat_detail(coef):
    return {"group_keys": (), "group_norms_sq": None, "coef": coef,
            "budgets": None}


def clipped_grad_sum(apply_fn, params, batch, **kw):
    """Returns (per-example losses, Σ_b clip(g_b), per-example norms²) —
    see :func:`clipped_grad_sum_detailed` for the keyword surface; this
    wrapper drops the detail dict."""
    losses, gsum, norms_sq, _ = clipped_grad_sum_detailed(
        apply_fn, params, batch, **kw)
    return losses, gsum, norms_sq


def clipped_grad_sum_detailed(apply_fn, params, batch, *, l2_clip: float,
                              strategy: str = "ghost",
                              norm_method: str = "auto",
                              conv_impl: str = "fgc", check: bool = False,
                              embed_method: str = "segsum",
                              conv_norm: str | None = None, overrides=None,
                              mem_budget: int | None = None, plan=None,
                              clip_policy=None, budgets=None,
                              prev_norms_sq=None, attn_norm: str = "auto"):
    """Returns (per-example losses, Σ_b clip(g_b), per-example norms²,
    detail).

    ``conv_norm`` (auto | ghost | pe) picks the conv norm realization; the
    historical ``None`` sentinel is a deprecated alias for ``"auto"`` (the
    pre-engine ghost/bk default of materializing — ``"pe"`` — must now be
    requested explicitly).  ``overrides`` pins individual layers by
    tap-name glob (planned strategy only); ``plan`` injects a pre-built,
    possibly deserialized ExecPlan, skipping the cached planner lookup.

    ``clip_policy`` (a :class:`~repro.core.clipping.ClipPolicy`; None =
    flat) selects the clipping mode; non-flat modes require the planned
    (``auto``) or book-keeping (``bk``) strategy, whose coefficient flow
    is per layer.  ``budgets`` injects a resolved (G,) per-layer budget
    array (else the policy's static split is resolved against the sorted
    group keys); ``prev_norms_sq`` feeds stale mode's lagged (B,) norms.

    ``detail``: ``group_keys`` (static tuple), ``group_norms_sq`` ((G, B)
    under per_layer, else None), ``coef`` (the applied coefficients —
    (B,) flat/stale, (G, B) per_layer), ``budgets`` ((G,) under
    per_layer, else None).
    """
    mode = clip_policy.mode if clip_policy is not None else "flat"
    if mode != "flat" and strategy not in ("auto", "bk"):
        raise ValueError(
            f"clipping mode {mode!r} requires strategy 'auto' or 'bk', "
            f"got {strategy!r}")
    if mode == "stale" and prev_norms_sq is None:
        raise ValueError(
            "stale clipping needs prev_norms_sq (the engine bootstraps "
            "the first step with flat clipping and threads the state)")
    if strategy == "auto":
        if plan is None:
            plan = costmodel.get_plan(
                apply_fn, params, batch, norm_method=norm_method,
                embed_method=embed_method, conv_norm=conv_norm or "auto",
                mem_budget=mem_budget or costmodel.STREAM_MEM_BUDGET,
                overrides=overrides, clip_mode=mode,
                clip_fused=(clip_policy.fused if clip_policy is not None
                            else True))
        return planned_clipped_sum(apply_fn, params, batch, plan,
                                   l2_clip=l2_clip, conv_impl=conv_impl,
                                   check=check, clip_policy=clip_policy,
                                   budgets=budgets,
                                   prev_norms_sq=prev_norms_sq)
    if strategy in ("naive", "multi", "crb"):
        if strategy == "naive":
            losses, pe = naive_per_example_grads(apply_fn, params, batch)
        elif strategy == "multi":
            losses, pe = multi_per_example_grads(apply_fn, params, batch)
        else:
            losses, pe = crb_per_example_grads(
                apply_fn, params, batch, conv_impl=conv_impl, check=check)
        norms_sq = _pe_tree_norms_sq(pe)
        coef = clip_coefficients(norms_sq, l2_clip)
        gsum = jax.tree.map(
            lambda g: jnp.einsum("b...,b->...", g.astype(jnp.float32), coef),
            pe)
        return losses, gsum, norms_sq, _flat_detail(coef)

    losses, caps, dtaps, metas = _capture(apply_fn, params, batch)
    group_keys, group_ns = group_norms_from_captures(
        params, caps, dtaps, metas, norm_method=norm_method,
        conv_impl=conv_impl, embed_method=embed_method,
        conv_norm=conv_norm or "auto", attn_norm=attn_norm)
    norms_sq = jnp.sum(group_ns, axis=0)

    if mode == "per_layer":
        if budgets is None:
            from repro.core.clipping import resolve_budgets
            budgets = resolve_budgets(clip_policy, l2_clip, group_keys)
        coef = lax.stop_gradient(
            per_layer_clip_coefficients(group_ns, budgets))      # (G, B)
        detail = {"group_keys": group_keys, "group_norms_sq": group_ns,
                  "coef": coef, "budgets": budgets}
        gi_of = {k: i for i, k in enumerate(group_keys)}

        def weight_of(meta):
            return coef[gi_of[group_key_of(meta.path)]]
    elif mode == "stale":
        coef = lax.stop_gradient(
            clip_coefficients(prev_norms_sq, l2_clip, mode="stale"))
        detail = _flat_detail(coef)

        def weight_of(meta):
            return coef
    else:
        coef = lax.stop_gradient(clip_coefficients(norms_sq, l2_clip))
        detail = _flat_detail(coef)

        def weight_of(meta):
            return coef

    if strategy == "ghost":
        def wloss(p):
            losses2 = apply_fn(p, batch, Tapper())
            return jnp.sum(losses2 * coef)

        STATS.forwards += 1
        STATS.backwards += 1
        gsum = jax.grad(wloss)(params)
        return losses, gsum, norms_sq, detail

    if strategy == "bk":
        acc: dict = {}
        for name, meta in metas.items():
            contrib = kinds.apply_kind(
                "contrib", meta, caps[name], dtaps[name],
                params_sub=get_subtree(params, meta.path),
                weights=weight_of(meta), conv_impl=conv_impl)
            _accumulate_param_grads(acc, meta.path, contrib)
        gsum = _grads_to_tree(acc)
        if check:
            missing = check_coverage(params, gsum)
            if missing:
                raise ValueError(f"bk missing param contribs: {missing}")
        return losses, gsum, norms_sq, detail

    raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# The planned (mixed per-layer) pipeline: strategy="auto"


def _batch_size(metas, dtaps):
    for name, meta in metas.items():
        if not meta.segmented:
            return jax.tree.leaves(dtaps[name])[0].shape[meta.scanned]
    for name, meta in metas.items():
        return meta.static["n_examples"]
    raise ValueError("no tapped layers")


def _norm_kwargs(lp):
    if lp.kind in ("dense", "seg_dense"):
        return {"norm_method": lp.norm_method}
    if lp.kind == "embed":
        return {"embed_method": lp.norm_method}
    if lp.kind == "conv":
        return {"conv_norm": lp.norm_method}
    if lp.kind == "attn":
        return {"attn_norm": lp.norm_method}
    return {}


def _group_norm_tag(n_sq, g, method: str, fused: bool = False):
    """Mark one plan group's realized (B,) squared norms for the static
    verifier (kind=group_norm): which group, which realized method, and
    whether a fused single-pass produced them."""
    return tag(n_sq, kind="group_norm", group=group_key_of(g.path),
               method=method, fused=fused)


def _planned_group_norm(g, plan, metas, caps, dtaps, params, conv_impl,
                        stash):
    """Phase-1 norm of one plan group: (B,) squared norms, stashing any
    per-example grads the chosen realization materialized."""
    psub = get_subtree(params, g.path)
    if g.norm_mode == "single":
        n = g.members[0]
        lp, meta = plan.layers[n], metas[n]
        if lp.stash:
            pe = kinds.apply_kind("pe_grad", meta, caps[n], dtaps[n],
                                  params_sub=psub, conv_impl=conv_impl)
            stash[n] = pe
            return _group_norm_tag(kinds._sumsq(pe), g, "stash")
        return _group_norm_tag(kinds.apply_kind(
            "norm_sq", meta, caps[n], dtaps[n], params_sub=psub,
            conv_impl=conv_impl, **_norm_kwargs(lp)), g, lp.norm_method)
    if g.norm_mode == "tied":
        n_e = next(n for n in g.members if metas[n].kind == "embed")
        n_d = next(n for n in g.members if metas[n].kind == "dense")
        n_g = kinds.apply_kind(
            "norm_sq", metas[n_e], caps[n_e], dtaps[n_e],
            params_sub=psub, **_norm_kwargs(plan.layers[n_e]))
        n_g = n_g + kinds.apply_kind(
            "norm_sq", metas[n_d], caps[n_d], dtaps[n_d],
            params_sub=psub, **_norm_kwargs(plan.layers[n_d]))
        return _group_norm_tag(n_g + kinds.tied_embed_head_cross(
            caps[n_e], dtaps[n_e], caps[n_d], dtaps[n_d]), g, "tied")
    # group_pe: exact generic fallback, materialized once
    pe_sum: dict = {}
    for n in g.members:
        pe = kinds.apply_kind("pe_grad", metas[n], caps[n], dtaps[n],
                              params_sub=psub, conv_impl=conv_impl)
        for k, v in pe.items():
            pe_sum[k] = pe_sum[k] + v if k in pe_sum else v
    if g.sum_method == "stash":
        stash[g.path] = pe_sum
    return _group_norm_tag(kinds._sumsq(pe_sum), g, "pe")


def _weighted_stash_sum(pe, w):
    return jax.tree.map(
        lambda leaf: jnp.einsum("b...,b->...", leaf.astype(jnp.float32), w),
        pe)


def _stale_group_norm_contrib(g, plan, metas, caps, dtaps, params, coef,
                              conv_impl, fused_ok, acc):
    """Stale-coefficient single pass over one plan group: the norm (for
    the *next* step's coefficients) and the weighted contribution come
    from the same captures, with the fused ``gram_norm_fused``
    realization where the plan selected it."""
    psub = get_subtree(params, g.path)
    if g.norm_mode == "single":
        n = g.members[0]
        lp, meta = plan.layers[n], metas[n]
        if lp.fused and fused_ok:
            n_g, contrib = kinds.apply_norm_contrib(
                meta, caps[n], dtaps[n], weights=coef, params_sub=psub,
                fused=True, conv_impl=conv_impl, **_norm_kwargs(lp))
            _accumulate_param_grads(acc, g.path, contrib)
            return _group_norm_tag(n_g, g, lp.norm_method, fused=True)
        if lp.stash:
            pe = kinds.apply_kind("pe_grad", meta, caps[n], dtaps[n],
                                  params_sub=psub, conv_impl=conv_impl)
            _accumulate_param_grads(acc, g.path, _weighted_stash_sum(pe, coef))
            return _group_norm_tag(kinds._sumsq(pe), g, "stash")
        n_g = kinds.apply_kind(
            "norm_sq", meta, caps[n], dtaps[n], params_sub=psub,
            conv_impl=conv_impl, **_norm_kwargs(lp))
        _accumulate_param_grads(acc, g.path, kinds.apply_kind(
            "contrib", meta, caps[n], dtaps[n], params_sub=psub,
            weights=coef, conv_impl=conv_impl))
        return _group_norm_tag(n_g, g, lp.norm_method)
    if g.norm_mode == "tied":
        stash: dict = {}
        n_g = _planned_group_norm(g, plan, metas, caps, dtaps, params,
                                  conv_impl, stash)
        for n in g.members:
            _accumulate_param_grads(acc, g.path, kinds.apply_kind(
                "contrib", metas[n], caps[n], dtaps[n], params_sub=psub,
                weights=coef, conv_impl=conv_impl))
        return n_g
    # group_pe: the materialized summed per-example grad serves both.
    pe_sum: dict = {}
    for n in g.members:
        pe = kinds.apply_kind("pe_grad", metas[n], caps[n], dtaps[n],
                              params_sub=psub, conv_impl=conv_impl)
        for k, v in pe.items():
            pe_sum[k] = pe_sum[k] + v if k in pe_sum else v
    _accumulate_param_grads(acc, g.path, _weighted_stash_sum(pe_sum, coef))
    return _group_norm_tag(kinds._sumsq(pe_sum), g, "pe")


def planned_clipped_sum(apply_fn, params, batch, plan, *, l2_clip: float,
                        conv_impl: str = "fgc", check: bool = False,
                        clip_policy=None, budgets=None, prev_norms_sq=None):
    """Execute a :class:`~repro.core.costmodel.ExecPlan`: one capture
    backward, per-layer planned norms (stashing any per-example grads the
    norm phase materialized), then the clipped sum from stashes /
    book-keeping contractions / at most one shared weighted backward.

    Returns (losses, gsum, total norms², detail) — see
    :func:`clipped_grad_sum_detailed` for the detail contract.

    The clipping mode generalizes the coefficient flow: ``flat`` applies
    one (B,) coefficient vector everywhere; ``per_layer`` gives each
    parameter group its own (B,) coefficients from its own norms and
    budget (so the shared weighted backward, which can only realize one
    weight per example, is never planned); ``stale`` knows every
    coefficient *entering* the pass and collapses norm + sum into one
    sweep over the captures, fused (``gram_norm_fused``) where the plan
    marked it.  The plan must have been built for the executing mode —
    a mismatch fails loudly, like any other stale-plan field.

    Layer metadata comes from the capture trace itself (the *live* metas),
    not the plan: a deserialized plan cannot carry ``local_vjp`` closures,
    and validating the name sets against each other makes a stale plan fail
    loudly instead of silently misassigning decisions."""
    mode = clip_policy.mode if clip_policy is not None else "flat"
    fused_ok = clip_policy.fused if clip_policy is not None else True
    costmodel.check_plan_matches(plan, clip_mode=mode)
    losses, caps, dtaps, metas = capture_backward(
        apply_fn, params, batch, plan.make_taps(), with_metas=True)
    if set(metas) != set(plan.layers):
        missing = sorted(set(plan.layers) - set(metas))
        extra = sorted(set(metas) - set(plan.layers))
        raise ValueError(
            f"ExecPlan {plan.fingerprint or '<unfingerprinted>'} "
            f"(mesh {costmodel.format_mesh(tuple(plan.mesh))}) does not "
            f"match this model: plan-only layers {missing}, model-only "
            f"layers {extra} — re-plan (stale or mismatched serialized "
            f"plan?)")
    group_keys = tuple(group_key_of(g.path) for g in plan.groups)
    if mode != "flat":
        bad = [group_keys[i] for i, g in enumerate(plan.groups)
               if g.sum_method == "backward"]
        if bad:
            raise ValueError(
                f"plan uses the shared weighted backward for {bad} — "
                f"incompatible with clipping mode {mode!r} (re-plan)")

    if mode == "stale":
        if prev_norms_sq is None:
            raise ValueError("stale clipping needs prev_norms_sq")
        coef = lax.stop_gradient(
            clip_coefficients(prev_norms_sq, l2_clip, mode="stale"))
        acc: dict = {}
        total = 0.0
        for g in plan.groups:
            total = total + _stale_group_norm_contrib(
                g, plan, metas, caps, dtaps, params, coef, conv_impl,
                fused_ok, acc)
        gsum = _grads_to_tree(acc)
        if check:
            missing = check_coverage(params, gsum)
            if missing:
                raise ValueError(f"auto missing param contribs: {missing}")
        return losses, gsum, total, _flat_detail(coef)

    stash: dict = {}
    group_ns = jnp.stack([
        _planned_group_norm(g, plan, metas, caps, dtaps, params, conv_impl,
                            stash)
        for g in plan.groups])                                   # (G, B)
    total = jnp.sum(group_ns, axis=0)

    if mode == "per_layer":
        if budgets is None:
            from repro.core.clipping import resolve_budgets
            budgets = resolve_budgets(clip_policy, l2_clip, group_keys)
        coef = lax.stop_gradient(
            per_layer_clip_coefficients(group_ns, budgets))      # (G, B)
        detail = {"group_keys": group_keys, "group_norms_sq": group_ns,
                  "coef": coef, "budgets": budgets}
        weights = list(coef)
    else:
        flat_coef = lax.stop_gradient(clip_coefficients(total, l2_clip))
        detail = _flat_detail(flat_coef)
        weights = [flat_coef] * len(plan.groups)

    wgrads = None
    if plan.needs_backward:
        def wloss(p):
            losses2 = apply_fn(p, batch, Tapper())
            return jnp.sum(losses2 * detail["coef"])

        STATS.forwards += 1
        STATS.backwards += 1
        wgrads = jax.grad(wloss)(params)

    acc: dict = {}
    for gi, g in enumerate(plan.groups):
        w = weights[gi]
        if g.sum_method == "backward":
            _accumulate_param_grads(acc, g.path, get_subtree(wgrads, g.path))
            continue
        if g.sum_method == "stash":
            pe = stash[g.members[0] if g.norm_mode == "single" else g.path]
            _accumulate_param_grads(acc, g.path, _weighted_stash_sum(pe, w))
            continue
        psub = get_subtree(params, g.path)
        for n in g.members:
            contrib = kinds.apply_kind(
                "contrib", metas[n], caps[n], dtaps[n], params_sub=psub,
                weights=w, conv_impl=conv_impl)
            _accumulate_param_grads(acc, g.path, contrib)

    gsum = _grads_to_tree(acc)
    if check:
        missing = check_coverage(params, gsum)
        if missing:
            raise ValueError(f"auto missing param contribs: {missing}")
    return losses, gsum, total, detail


def per_example_grads(apply_fn, params, batch, strategy: str = "crb", **kw):
    """Materialized per-example gradients (B leading on every leaf)."""
    if strategy == "naive":
        return naive_per_example_grads(apply_fn, params, batch)
    if strategy == "multi":
        return multi_per_example_grads(apply_fn, params, batch)
    if strategy == "crb":
        return crb_per_example_grads(apply_fn, params, batch, **kw)
    raise ValueError(
        f"strategy {strategy!r} does not materialize per-example grads")
