"""Rényi differential privacy accountant for subsampled Gaussian mechanisms.

Implements the moments-accountant bound of Abadi et al. (2016) in its RDP
form (Mironov 2017; Mironov-Talwar-Zhang 2019 for the sampled Gaussian):
for integer orders α ≥ 2 and Poisson sampling rate q,

    RDP(α) = 1/(α−1) · log Σ_{k=0}^{α} C(α,k) (1−q)^{α−k} q^k
                           · exp(k(k−1)/(2σ²))

composed linearly over steps, then converted to (ε, δ) via
ε = min_α [ RDP_total(α) + log(1/δ)/(α−1) ].

Pure numpy — no jax dependency — so the accountant can run on the host
alongside a training loop.

Clipping-mode accounting notes
------------------------------
The accountant only assumes the mechanism's L2 sensitivity is the ``C``
the noise σC was calibrated against.

  * ``flat``      — each example's contribution is clipped to ‖·‖ ≤ C:
    sensitivity C, exactly.
  * ``per_layer`` — layer l clipped to C_l; an example's total
    contribution satisfies ‖·‖² = Σ_l ‖clip_l‖² ≤ Σ_l C_l², so the
    budget invariant Σ_l C_l² = C² (enforced by
    ``clipping.resolve_budgets`` and checked with
    :func:`clipping_sensitivity`) keeps the sensitivity at C with the
    same accountant.
  * ``stale``     — coefficients come from the *previous* step's norms,
    so this step's contribution is bounded by C only under the lagged
    norms, not unconditionally; the engine's bootstrap step is exact,
    and steady-state steps are "exactly-as-specified-stale" (the oracle
    suite pins that semantics).  Treat ε reported under stale clipping
    as conditional on the staleness assumption — this is the documented
    trade of Lee & Kifer-style reorganized clipping passes.
"""
from __future__ import annotations

import math

import numpy as np

DEFAULT_ORDERS = tuple(range(2, 64)) + tuple(range(64, 513, 8))


def clipping_sensitivity(budgets) -> float:
    """L2 sensitivity of a per-layer-clipped per-example contribution:
    ``sqrt(Σ_l C_l²)``.  The noise calibration σ·C stays valid exactly
    when this equals the configured ``C`` — the invariant every budget
    split must preserve (property-tested in tests/test_clip_modes.py)."""
    b = np.asarray(budgets, np.float64)
    return float(np.sqrt(np.sum(b * b)))


def _log_binom(n: int, k: int) -> float:
    return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)


def rdp_subsampled_gaussian(q: float, sigma: float,
                            orders=DEFAULT_ORDERS) -> np.ndarray:
    """Per-step RDP at each order."""
    if sigma <= 0:
        return np.full(len(orders), np.inf)
    out = []
    for a in orders:
        a = int(a)
        if q >= 1.0:
            out.append(a / (2 * sigma ** 2))
            continue
        if q == 0.0:
            out.append(0.0)
            continue
        terms = []
        for k in range(a + 1):
            lt = (_log_binom(a, k) + (a - k) * math.log1p(-q)
                  + k * math.log(q) + k * (k - 1) / (2 * sigma ** 2))
            terms.append(lt)
        m = max(terms)
        lse = m + math.log(sum(math.exp(t - m) for t in terms))
        out.append(lse / (a - 1))
    return np.asarray(out)


def eps_from_rdp(rdp_total: np.ndarray, orders, delta: float) -> float:
    orders = np.asarray(orders, dtype=np.float64)
    eps = rdp_total + math.log(1.0 / delta) / (orders - 1)
    return float(np.min(eps))


class LedgerMismatch(ValueError):
    """A restored ledger describes a different mechanism (q, σ, orders)
    than the live accountant — continuing would compose RDP curves of two
    different mechanisms under one ε, silently corrupting the guarantee."""


class PrivacyAccountant:
    """Tracks composition over training steps.

    The accountant's full state is its ledger — ``state_dict()`` /
    ``load_state_dict()`` round-trip it through checkpoints so a restart
    resumes the ε composition exactly where the checkpoint left it (the
    replayed steps re-run the *same* deterministic mechanism outputs, so
    they are not new releases and must not be double-counted)."""

    def __init__(self, sampling_rate: float, noise_multiplier: float,
                 orders=DEFAULT_ORDERS):
        self.q = float(sampling_rate)
        self.sigma = float(noise_multiplier)
        self.orders = tuple(orders)
        self._per_step = rdp_subsampled_gaussian(self.q, self.sigma,
                                                 self.orders)
        self.steps = 0

    def step(self, n: int = 1):
        self.steps += n

    # -- ledger (de)serialization ---------------------------------------

    def state_dict(self) -> dict:
        """JSON-able ledger: the composed step count plus the mechanism
        parameters it was composed under (so a restore can refuse to graft
        it onto a different mechanism)."""
        return {"steps": int(self.steps), "q": self.q, "sigma": self.sigma,
                "orders": [int(a) for a in self.orders]}

    def load_state_dict(self, state: dict):
        """Resume a checkpointed ledger.  Fails loudly (LedgerMismatch) if
        the checkpoint was accounted under different mechanism parameters
        — that is a privacy bug, not a resumable condition."""
        for field, mine in (("q", self.q), ("sigma", self.sigma)):
            theirs = float(state[field])
            if theirs != mine:
                raise LedgerMismatch(
                    f"checkpointed ledger has {field}={theirs}, this "
                    f"accountant runs {field}={mine}; refusing to resume "
                    f"a ledger accounted under a different mechanism")
        if "orders" in state and tuple(state["orders"]) != \
                tuple(int(a) for a in self.orders):
            raise LedgerMismatch(
                "checkpointed ledger used different RDP orders; refusing "
                "to resume (ε would be composed over mismatched curves)")
        self.steps = int(state["steps"])

    @classmethod
    def from_state(cls, state: dict) -> "PrivacyAccountant":
        acct = cls(sampling_rate=state["q"], noise_multiplier=state["sigma"],
                   orders=tuple(state.get("orders", DEFAULT_ORDERS)))
        acct.steps = int(state["steps"])
        return acct

    def reset(self):
        """Back to zero composed steps (a from-scratch in-process restart
        with no checkpoint to resume from)."""
        self.steps = 0

    def epsilon(self, delta: float = 1e-5) -> float:
        if self.sigma <= 0:
            return float("inf")
        return eps_from_rdp(self._per_step * self.steps, self.orders, delta)

    def report(self, delta: float = 1e-5) -> str:
        return (f"DP: steps={self.steps} q={self.q} sigma={self.sigma} "
                f"-> eps={self.epsilon(delta):.3f} at delta={delta}")
