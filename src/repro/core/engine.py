"""PrivacyEngine: the plan-first DP-SGD public surface.

Make-private-once, step-many (the Opacus-style engine shape of Subramani
et al. 2020 and Lee & Kifer 2020): construct the engine once from the
model's ``apply_fn``, parameter/batch *shapes* and a :class:`DPConfig`;
the per-layer :class:`~repro.core.costmodel.ExecPlan` is then a
first-class value —

  * ``engine.plan()``          the frozen plan (built once, cached);
  * ``engine.explain()``       per-layer table of chosen norm/sum
                               realizations with predicted FLOPs/bytes;
  * ``plan.to_json()``         cross-process plan caching keyed on the
                               model+shape fingerprint (pre-load a store
                               with ``costmodel.load_plan_store`` and the
                               engine never pays a probe);
  * ``engine.microbatches()``  plan-driven ``microbatches="auto"`` from
                               the plan's peak-memory estimates;
  * ``engine.private_step()``  one jitted closure over the plan fusing
                               gradient + clip + noise + optimizer update,
                               with accountant bookkeeping on the host;
  * ``engine.noisy_grad()``    the eager/jit-composable gradient-only
                               path (what ``private_step`` jits).

Steady state executes exactly one forward and one backward per step for
``strategy="auto"`` (counters in :data:`repro.core.tapper.STATS`).

Sharded execution: pass ``mesh=`` a ``jax.sharding.Mesh`` and the plan
is built mesh-aware (per-device memory, collective-bytes cost terms, the
mesh folded into the fingerprint) while ``private_step`` runs under
``jax.jit`` with explicit ``NamedSharding``s — the batch sharded over
the data axes, params/optimizer state/PRNG key replicated.  Per-example
norms are reduced globally by SPMD (clip coefficients see the psum'd
global norm) and the noise is generated from the one replicated key, so
every device adds the *same* noise instead of per-shard draws: the
sharded step equals the single-device step up to reduction order.  A
mesh *spec* ("data:8", axes dict) is also accepted for planning-only
use on hosts without the devices.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import costmodel
from repro.core.clipping import (DPConfig, dp_gradient, resolve_budgets,
                                 resolve_microbatches)
from repro.core.privacy import PrivacyAccountant, clipping_sensitivity


def _spec_of(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(tuple(a.shape), a.dtype), tree)


class KeyProvenanceError(ValueError):
    """An explicit PRNG key contradicts the engine's deterministic noise
    stream (``fold_in(PRNGKey(run_seed), step)``).  Raised instead of
    silently drawing from the wrong key: noise from an unaccounted stream
    breaks the replay guarantee the accountant ledger depends on."""


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One firing of the engine's mispredict loop: measured step time
    diverged from the calibrated prediction beyond the threshold, the
    calibration was retimed from the observation, and the plan was
    rebuilt under the new constants.  Surfaced in :meth:`explain` and
    (when a monitor is attached) in ``StepMonitor.replans``."""

    step: int                 # step the divergence was confirmed at (-1 unknown)
    ratio: float              # measured / predicted at trigger time
    predicted_s: float
    measured_s: float
    old_calibration: str      # digests
    new_calibration: str
    old_fingerprint: str
    new_fingerprint: str
    plan_changed: bool        # did any layer's realization actually flip


def _resolve_optimizer(optimizer) -> Callable:
    if callable(optimizer):
        return optimizer
    from repro.optim import adamw_update, sgdm_update
    table = {"adamw": adamw_update, "sgdm": sgdm_update}
    try:
        return table[optimizer]
    except KeyError:
        raise ValueError(f"unknown optimizer {optimizer!r}; pass one of "
                         f"{sorted(table)} or an update callable") from None


class PrivacyEngine:
    """Plan-first DP-SGD driver bound to one (model, batch shape, config).

    Parameters:
      apply_fn:   ``apply_fn(params, batch, tapper) -> (B,) losses``.
      params:     parameter pytree (arrays or ShapeDtypeStructs — only
                  shapes/dtypes are retained).
      batch_spec: an example batch (arrays or ShapeDtypeStructs) fixing
                  the step's batch shapes.
      dp:         :class:`DPConfig`.
      optimizer:  "adamw" | "sgdm" | ``update(grads, state, params, *, lr,
                  weight_decay) -> (params, state)``.
      lr:         learning rate, or a callable ``lr(opt_step) -> lr`` for
                  schedules (traced inside the jitted step).
      sampling_rate / accountant: privacy accounting — pass either the
                  Poisson sampling rate (an accountant is built) or an
                  existing :class:`PrivacyAccountant`.
      plan:       inject a pre-built or deserialized ExecPlan (must match
                  the model, shapes, and mesh; validated up front with
                  named-field errors and again at execution).
      mesh:       a ``jax.sharding.Mesh`` — plans become mesh-aware and
                  ``private_step`` runs sharded (batch over the data
                  axes, params/opt/key replicated).  A mesh *spec*
                  (``"data:8"``, axes dict/tuple) plans for that topology
                  without requiring the devices (no sharded execution).
      param_axes: the logical-axes pytree ``model.init`` returns next to
                  the params.  On a mesh with model axes this partitions
                  params (and congruent optimizer moments) per
                  ``launch.sharding.PARAM_RULES`` — tensor-sharded
                  dense/conv layers then *execute* sharded.  Ignored on
                  pure-data meshes.
      calibration: measured cost constants for planning.  ``None``
                  consults the process registry for (live hardware,
                  mesh); on a mesh with model axes a registry miss
                  auto-measures once per (hardware, mesh) per process
                  (a 2D plan priced from ``ANALYTIC_FALLBACK`` would
                  invent the data/model bandwidth ratio); pass
                  ``"analytic"`` to explicitly opt out and plan from the
                  analytic constants.  A ``repro.calibrate.Calibration``
                  is validated
                  strictly against the live hardware and this mesh
                  (named errors on mismatch); a path string loads a
                  stored blob *softly* — unusable blobs degrade to the
                  analytic constants with a
                  ``CalibrationFallbackWarning``; the literal
                  ``"measure"`` runs the microbenchmark harness now
                  (once per (hardware, mesh) per process).
      mispredict_threshold: relative divergence of measured vs predicted
                  step time that triggers an automatic re-plan (e.g.
                  ``0.5`` = re-plan beyond ±50%).  Feed measured step
                  wall-clock to :meth:`observe_step_time`; ``None``
                  disables the loop.  Re-plans retime the calibration
                  from the observation, rebuild the plan under the new
                  constants, and are surfaced in :meth:`explain`,
                  :attr:`replan_events`, and the attached ``monitor``.
      monitor:    a ``runtime.monitor.StepMonitor`` to surface re-plan
                  events in (``monitor.replans``).
      run_seed:   seed of the deterministic per-step noise stream: step
                  ``n``'s noise key is ``fold_in(PRNGKey(run_seed), n)``
                  (:meth:`noise_key`), a pure function of (run_seed, n)
                  — so a killed-and-resumed run replays *exactly* the
                  noise an uninterrupted run would have drawn, never a
                  fresh draw (which would break the accounted mechanism).
                  Pass ``step=`` to :meth:`private_step`/:meth:`noisy_grad`
                  to use the stream.
    """

    def __init__(self, apply_fn: Callable, params, batch_spec,
                 dp: DPConfig | None = None, *, optimizer="adamw",
                 lr=1e-3, weight_decay: float = 0.0,
                 sampling_rate: float | None = None,
                 accountant: PrivacyAccountant | None = None,
                 plan: costmodel.ExecPlan | None = None,
                 mesh=None, param_axes=None, run_seed: int | None = None,
                 calibration=None,
                 mispredict_threshold: float | None = 0.5,
                 monitor=None):
        self.apply_fn = apply_fn
        self.dp = dp if dp is not None else DPConfig()
        self._params_spec = _spec_of(params)
        self._batch_spec = _spec_of(batch_spec)
        self._update_fn = _resolve_optimizer(optimizer)
        self._optimizer_name = optimizer if isinstance(optimizer, str) else None
        self._opt_spec = None   # recorded lazily; see _record_opt_spec
        self._lr = lr
        self._weight_decay = weight_decay
        if accountant is None and sampling_rate is not None:
            accountant = PrivacyAccountant(
                sampling_rate=sampling_rate,
                noise_multiplier=self.dp.noise_multiplier)
        self.accountant = accountant
        self.mesh = mesh if isinstance(mesh, jax.sharding.Mesh) else None
        self._mesh_axes = costmodel.mesh_axes(mesh)
        self._param_axes = param_axes
        if self.mesh is not None:
            d = costmodel.mesh_data_size(self._mesh_axes)
            for kp, leaf in jax.tree_util.tree_leaves_with_path(
                    self._batch_spec):
                if leaf.shape and leaf.shape[0] % d:
                    raise ValueError(
                        f"batch leaf {jax.tree_util.keystr(kp)} leading dim "
                        f"{leaf.shape[0]} is not divisible by the mesh's "
                        f"data-parallel degree {d} "
                        f"({costmodel.format_mesh(self._mesh_axes)})")
        self._calibration = self._resolve_calibration_arg(calibration)
        self.mispredict_threshold = mispredict_threshold
        self._monitor = monitor
        self.replan_events: list[ReplanEvent] = []
        self._step_ema: float | None = None
        self._step_obs = 0
        if plan is not None and self.dp.strategy == "auto":
            # Fail loudly *now* on a stale injected plan, naming the
            # offending field (mesh / batch / clip mode / calibration /
            # fingerprint).
            costmodel.check_plan_matches(
                plan, mesh=self._mesh_axes,
                batch_sig=costmodel._shape_sig(self._batch_spec),
                fingerprint=self._fingerprint(),
                clip_mode=self.dp.clipping.mode,
                calibration="" if self._calibration is None
                else self._calibration)
        self._plan = plan
        self.run_seed = run_seed
        self._run_key = (None if run_seed is None
                         else jax.random.PRNGKey(run_seed))
        # Cross-step clipping state: stale mode's lagged norms, and the
        # per-layer "auto" budget split tracked from observed norm
        # quantiles.  Device arrays where possible (no host sync on the
        # stale path).
        self._prev_norms_sq = None
        self._budgets = None
        self._budget_q = None

    # -- planning ----------------------------------------------------------

    def _resolve_calibration_arg(self, calibration):
        """See ``calibration`` in the class docstring: registry lookup /
        strict Calibration / ``"measure"`` / soft path load."""
        from repro import calibrate
        if calibration == "analytic":
            return None
        if calibration is None:
            calib = calibrate.lookup(self._mesh_axes)
            if calib is not None:
                return calib
            # 2D-mesh default: a fresh engine on a data×model mesh would
            # otherwise price the model axis from ANALYTIC_FALLBACK (the
            # PR-8 follow-up) — measure once per (hardware, mesh) per
            # process.  1D meshes keep the analytic default: their single
            # ring has no cross-axis ratio to get wrong, and measuring
            # would perturb plan fingerprints test/CI lanes pin.
            if (self.mesh is not None
                    and costmodel.mesh_model_axes(self._mesh_axes)):
                import warnings
                try:
                    return calibrate.get_or_measure(self._mesh_axes)
                except calibrate.CalibrationError as e:
                    warnings.warn(
                        f"auto-calibration for mesh "
                        f"{costmodel.format_mesh(self._mesh_axes)} failed "
                        f"({type(e).__name__}: {e}); planning with the "
                        f"analytic fallback constants",
                        calibrate.CalibrationFallbackWarning, stacklevel=2)
            return None
        if isinstance(calibration, calibrate.Calibration):
            calibration.validate_for(calibrate.hardware_signature(),
                                     self._mesh_axes)
            return calibration
        if calibration == "measure":
            return calibrate.get_or_measure(self._mesh_axes)
        return calibrate.load_or_fallback(str(calibration),
                                          mesh=self._mesh_axes)

    @property
    def calibration(self):
        """The calibration this engine plans under (``None`` = analytic
        fallback constants)."""
        return self._calibration

    def _planner_opts(self) -> dict:
        return dict(self.dp.planner_opts(), mesh=self._mesh_axes,
                    calibration=self._calibration)

    def _fingerprint(self) -> str:
        return costmodel.plan_fingerprint(
            self.apply_fn, self._params_spec, self._batch_spec,
            **self._planner_opts())

    def fingerprint(self, mesh=None) -> str:
        """The plan fingerprint for this engine's (model, shapes, config)
        — what a checkpoint pins.  ``mesh=`` re-keys it under a different
        topology: the elastic-resume cross-check, distinguishing "this
        checkpoint is the same run on another mesh" (re-plan and resume)
        from "the model or planner config changed" (refuse)."""
        if mesh is None:
            return self._fingerprint()
        opts = dict(self._planner_opts(), mesh=costmodel.mesh_axes(mesh))
        return costmodel.plan_fingerprint(
            self.apply_fn, self._params_spec, self._batch_spec, **opts)

    def plan(self) -> costmodel.ExecPlan:
        """The full-batch ExecPlan (built once; cache/store hits are free)."""
        if self._plan is None:
            self._plan = costmodel.get_plan(
                self.apply_fn, self._params_spec, self._batch_spec,
                **self._planner_opts())
        return self._plan

    # -- measured-cost feedback (the mispredict loop) ----------------------

    def predicted_step_seconds(self) -> float:
        """Calibrated prediction of one step's wall-clock under the
        current plan — what :meth:`observe_step_time` compares against."""
        return costmodel.predicted_step_seconds(self.plan(),
                                                self._calibration)

    def observe_step_time(self, seconds: float,
                          step: int | None = None) -> ReplanEvent | None:
        """Record one executed step's measured wall-clock.  An EMA of the
        observations is compared against :meth:`predicted_step_seconds`;
        when the relative divergence exceeds ``mispredict_threshold``
        (after ≥ 2 observations, so one compile-tainted step can't
        trigger), the calibration is retimed from the observation, the
        plan is rebuilt under the new constants, and the returned
        :class:`ReplanEvent` is appended to :attr:`replan_events` (and
        the attached monitor).  Returns ``None`` when no re-plan fired.
        Inert without a calibration or with ``mispredict_threshold=None``
        — the analytic constants carry no time unit worth trusting."""
        if (self.mispredict_threshold is None or self._calibration is None
                or self.dp.strategy != "auto"):
            return None
        seconds = float(seconds)
        self._step_obs += 1
        self._step_ema = (seconds if self._step_ema is None
                          else 0.5 * self._step_ema + 0.5 * seconds)
        if self._step_obs < 2:
            return None
        predicted = self.predicted_step_seconds()
        ratio = self._step_ema / max(predicted, 1e-12)
        if abs(ratio - 1.0) <= self.mispredict_threshold:
            return None
        return self._replan(step, ratio, predicted, self._step_ema)

    def _replan(self, step, ratio, predicted_s, measured_s) -> ReplanEvent:
        """Retime the calibration from the observed divergence and
        rebuild the plan (and the jitted step) under the new constants."""
        from repro import calibrate
        old = self._calibration
        old_plan = self.plan()
        new = old.retimed(predicted_s=predicted_s, measured_s=measured_s,
                          coll_bytes=old_plan.total_coll_bytes,
                          coll_bytes_by_axis=old_plan.total_coll_bytes_by_axis)
        calibrate.register(new)
        self._calibration = new
        self._plan = None
        self.__dict__.pop("_jit_step", None)
        self._step_ema = None
        self._step_obs = 0
        new_plan = self.plan()
        event = ReplanEvent(
            step=-1 if step is None else int(step), ratio=float(ratio),
            predicted_s=float(predicted_s), measured_s=float(measured_s),
            old_calibration=old.digest(), new_calibration=new.digest(),
            old_fingerprint=old_plan.fingerprint,
            new_fingerprint=new_plan.fingerprint,
            plan_changed=old_plan.describe() != new_plan.describe())
        self.replan_events.append(event)
        if self._monitor is not None:
            self._monitor.record_replan(event.step, event.ratio)
        return event

    def _explain_calibration(self) -> str:
        if self._calibration is None:
            lines = ["calibration: none — planning with the analytic "
                     "fallback constants (costmodel.ANALYTIC_FALLBACK)"]
        else:
            c = self._calibration
            coll = {a: f"{bw / 1e9:.1f} GB/s"
                    for a, bw in c.collective_bytes_per_second.items()}
            lines = [
                f"calibration: {c.digest()} (source={c.source}, hw="
                f"{c.hardware}) flops/s={c.flops_per_second:.3g} "
                f"hbm={c.hbm_bytes_per_second / 1e9:.1f} GB/s"
                + (f" collective={coll}" if coll else ""),
                f"predicted step: {self.predicted_step_seconds() * 1e6:.0f}"
                f" us; mispredict threshold: "
                + (f"±{self.mispredict_threshold:g}"
                   if self.mispredict_threshold is not None
                   else "disabled")]
        for ev in self.replan_events:
            lines.append(
                f"re-plan @ step {ev.step}: measured/predicted = "
                f"{ev.ratio:.2f}x ({ev.measured_s * 1e6:.0f} us vs "
                f"{ev.predicted_s * 1e6:.0f} us), calibration "
                f"{ev.old_calibration} -> {ev.new_calibration}, plan "
                + ("changed" if ev.plan_changed else "unchanged")
                + f" ({ev.old_fingerprint} -> {ev.new_fingerprint})")
        return "\n".join(lines)

    def explain(self) -> str:
        """Human-readable per-layer plan table (see ExecPlan.explain),
        plus the calibration block: active measured constants (or the
        analytic fallback), the predicted step time, the mispredict
        threshold, and every re-plan event fired so far."""
        clip = self.dp.clipping
        header = (f"PrivacyEngine: strategy={self.dp.strategy} "
                  f"C={self.dp.l2_clip} sigma={self.dp.noise_multiplier} "
                  f"clipping={clip.mode}"
                  + (f"(budgets={clip.budgets})"
                     if clip.mode == "per_layer" else "")
                  + f" microbatches={self.microbatches()}"
                  + ("" if self.dp.microbatches != "auto" else " (auto)")
                  + (f" mesh={costmodel.format_mesh(self._mesh_axes)}"
                     if self._mesh_axes else ""))
        cal = self._explain_calibration()
        if self.dp.strategy != "auto":
            return (header + f"\nfixed strategy {self.dp.strategy!r}: the "
                    "planner is bypassed; plan below is advisory.\n"
                    + cal + "\n" + self.plan().explain())
        return header + "\n" + cal + "\n" + self.plan().explain()

    def save_plan(self, path: str):
        """Persist every plan this engine executes with — the full-batch
        plan and, when microbatching splits the step, the per-microbatch
        plan too — so a loading process never probes."""
        plans = [self.plan()]
        exec_plan = self._exec_plan()
        if exec_plan is not None \
                and exec_plan.fingerprint != plans[0].fingerprint:
            plans.append(exec_plan)
        costmodel.save_plan_store(
            path, plans,
            calibrations=[self._calibration] if self._calibration else None)

    def microbatches(self) -> int:
        """The resolved microbatch count (plan-driven for ``"auto"``) —
        the same resolution rule legacy ``dp_gradient`` applies."""
        plan = self._plan
        if self.dp.microbatches == "auto" and self.dp.strategy == "auto":
            plan = self.plan()
        return resolve_microbatches(self.apply_fn, self._params_spec,
                                    self._batch_spec, self.dp, plan=plan,
                                    mesh=self._mesh_axes)

    def _exec_plan(self) -> costmodel.ExecPlan | None:
        """The plan matching the shapes the step actually executes: the
        full-batch plan, or a per-microbatch-shape plan when splitting."""
        if self.dp.strategy != "auto":
            return None
        m = self.microbatches()
        if m == 1:
            return self.plan()
        mb_spec = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (s.shape[0] // m,) + tuple(s.shape[1:]), s.dtype),
            self._batch_spec)
        return costmodel.get_plan(self.apply_fn, self._params_spec, mb_spec,
                                  **self._planner_opts())

    # -- execution ---------------------------------------------------------

    def noise_key(self, step: int):
        """Step ``step``'s noise key: ``fold_in(PRNGKey(run_seed), step)``.
        A pure function of (run_seed, step) — independent of how many
        times the process died and resumed on the way to ``step`` — so
        replayed steps re-add the *same* noise and the checkpointed
        accountant ledger stays the truth (deterministic replay releases
        nothing new)."""
        if self._run_key is None:
            raise ValueError(
                "engine has no noise stream; construct with run_seed=")
        return jax.random.key_data(jax.random.fold_in(self._run_key, step))

    def _check_key(self, key, step=None):
        if key is None and step is not None and self._run_key is not None:
            return self.noise_key(step)
        if key is None:
            if self.dp.noise_multiplier > 0:
                raise ValueError(
                    "noise_multiplier > 0 requires a PRNG key per step "
                    "(or construct the engine with run_seed= and pass "
                    "step=)")
            return jax.random.PRNGKey(0)
        if step is not None:
            # An explicit key together with step= claims to be the
            # stream's key for that step — verify, don't trust.
            if self._run_key is None:
                raise KeyProvenanceError(
                    f"key= passed with step={step} but the engine has no "
                    f"noise stream (construct with run_seed=) — cannot "
                    f"verify the key belongs to step {step}")
            data = key
            if isinstance(key, jax.core.Tracer):
                raise KeyProvenanceError(
                    f"key= passed with step={step} is a tracer — its "
                    f"provenance cannot be checked; pass step= alone and "
                    f"let the engine derive fold_in(run_key, {step})")
            if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
                data = jax.random.key_data(key)
            if not np.array_equal(np.asarray(data),
                                  np.asarray(self.noise_key(step))):
                raise KeyProvenanceError(
                    f"key= does not match the deterministic stream's key "
                    f"for step={step} (fold_in(PRNGKey({self.run_seed}), "
                    f"{step})) — replaying this step would draw different "
                    f"noise than the accounted run")
        return key

    def noisy_grad(self, params, batch, key=None, denom: int | None = None,
                   *, step: int | None = None):
        """(mean loss, noised clipped mean gradient, aux).  Eager — safe to
        call under an outer ``jax.jit``; ``private_step`` is the pre-jitted
        all-in-one.  Cross-step clipping state (stale norms, auto budgets)
        is threaded exactly as in ``private_step``.  ``step=`` draws the
        noise from the deterministic stream (``run_seed`` engines)."""
        cfg = dataclasses.replace(self.dp, microbatches=self.microbatches())
        out = dp_gradient(self.apply_fn, params, batch, cfg=cfg,
                          key=self._check_key(key, step), denom=denom,
                          plan=self._exec_plan(),
                          clip_state=self._clip_state())
        self._absorb_clip_aux(out[2])
        return out

    # -- cross-step clipping state ------------------------------------------

    def clip_state_dict(self) -> dict:
        """Host-side snapshot of the cross-step clipping state — the
        stale lagged norms and the per-layer auto-budget split + tracked
        quantiles.  This *must* ride in every checkpoint: a stale-mode
        restart without ``prev_norms_sq`` would re-run the flat bootstrap
        (different coefficients than the uninterrupted run), and an
        auto-budget restart without ``budget_q`` would re-split the clip
        budget from scratch — both silently change what the accounted
        mechanism released."""
        out = {}
        if self._prev_norms_sq is not None:
            out["prev_norms_sq"] = np.asarray(self._prev_norms_sq)
        if self._budgets is not None:
            out["budgets"] = np.asarray(self._budgets)
        if self._budget_q is not None:
            out["budget_q"] = np.asarray(self._budget_q)
        return out

    def load_clip_state(self, state: dict | None):
        """Install a checkpointed :meth:`clip_state_dict` (missing keys
        reset to empty — a flat-mode checkpoint carries none)."""
        state = dict(state or {})
        pn = state.get("prev_norms_sq")
        self._prev_norms_sq = None if pn is None else jnp.asarray(pn)
        b = state.get("budgets")
        self._budgets = None if b is None else jnp.asarray(b)
        q = state.get("budget_q")
        self._budget_q = None if q is None else np.asarray(q, np.float64)

    def reset_clip_state(self):
        """Drop all cross-step clipping state (a from-scratch restart:
        stale mode re-bootstraps, auto budgets re-track)."""
        self.load_clip_state(None)

    def _clip_state(self) -> dict:
        """The clip_state dict for the next step.  Structure changes only
        once (the stale bootstrap → steady transition), so ``jax.jit``
        retraces at most twice."""
        clip = self.dp.clipping
        if clip.mode == "stale" and self._prev_norms_sq is not None:
            return {"prev_norms_sq": self._prev_norms_sq}
        if clip.mode == "per_layer" and clip.budgets == "auto":
            if self._budgets is None:
                keys = tuple("/".join(str(p) for p in g.path)
                             for g in self.plan().groups)
                self._budgets = resolve_budgets(
                    clip, self.dp.l2_clip, keys, observed=self._budget_q)
            # The auto split must keep the clipped sum's sensitivity at C
            # (Σ C_l² = C²) or the σC noise calibration breaks.
            sens = clipping_sensitivity(self._budgets)
            if abs(sens - self.dp.l2_clip) > 1e-3 * self.dp.l2_clip:
                raise AssertionError(
                    f"auto budget split broke the sensitivity invariant: "
                    f"sqrt(sum C_l^2) = {sens} != C = {self.dp.l2_clip}")
            return {"budgets": self._budgets}
        return {}

    def _absorb_clip_aux(self, aux: dict):
        """Host-side bookkeeping after a step: thread stale norms, update
        the per-layer norm quantile EMA driving ``budgets="auto"``."""
        clip = self.dp.clipping
        leaves = jax.tree.leaves(aux)
        if leaves and isinstance(leaves[0], jax.core.Tracer):
            # noisy_grad under an outer jit: the caller owns the loop and
            # must thread the clip state itself — storing tracers as
            # cross-step state would poison the next eager step.
            return
        if clip.mode == "stale":
            self._prev_norms_sq = aux["clip_state"]["prev_norms_sq"]
        elif clip.mode == "per_layer" and clip.budgets == "auto":
            q = np.quantile(np.asarray(aux["per_layer_norms"], np.float64),
                            clip.quantile, axis=1)
            q = np.maximum(q, 1e-12)
            if self._budget_q is None:
                self._budget_q = q
            else:
                self._budget_q = clip.ema * self._budget_q \
                    + (1.0 - clip.ema) * q
            keys = tuple("/".join(str(p) for p in g.path)
                         for g in self.plan().groups)
            self._budgets = resolve_budgets(
                clip, self.dp.l2_clip, keys, observed=self._budget_q)

    def _step_fn(self):
        """The raw (unjitted) step closure over the plan — what
        ``private_step`` jits and what the static verifier traces."""
        cfg = dataclasses.replace(self.dp, microbatches=self.microbatches())
        plan = self._exec_plan()
        update_fn, lr, wd = self._update_fn, self._lr, self._weight_decay
        apply_fn = self.apply_fn

        def step(params, opt, batch, key, clip_state):
            loss, grad, aux = dp_gradient(apply_fn, params, batch, cfg=cfg,
                                          key=key, plan=plan,
                                          clip_state=clip_state)
            lr_t = lr(opt["step"]) if callable(lr) else lr
            params, opt = update_fn(grad, opt, params, lr=lr_t,
                                    weight_decay=wd)
            return params, opt, loss, aux

        return step

    def _step_shardings(self):
        """(in_shardings, out_shardings) for the jitted step, or ``None``
        off-mesh.  Batch over the data axes; PRNG key, clip state, loss
        and aux replicated.  Params (and congruent optimizer moments) are
        replicated on a pure-data mesh; with ``param_axes=`` on a mesh
        that has model axes they are partitioned per the logical-axis
        rules (``launch.sharding.PARAM_RULES``), so tensor-sharded layers
        execute sharded: XLA inserts the partial-Gram / norm psums over
        ``model`` and the noise — drawn from the one replicated key, with
        value-semantic counter-based PRNG — lands sharded consistently
        with the param layout."""
        if self.mesh is None:
            return None
        from repro.launch.sharding import batch_sharding, param_sharding
        from jax.sharding import NamedSharding, PartitionSpec as P
        repl = NamedSharding(self.mesh, P())
        batch_sh = batch_sharding(self._batch_spec, self.mesh)
        if (self._param_axes is None
                or not costmodel.mesh_model_axes(self._mesh_axes)):
            return (repl, repl, batch_sh, repl, repl), repl
        param_sh = param_sharding(self._param_axes, self.mesh,
                                  shapes_tree=self._params_spec)
        # Optimizer moments inherit the param layout (ZeRO-style: every
        # moment shard lives once).  Custom optimizer callables have no
        # entry in the named table; their layout is derived from the
        # recorded state pytree instead (see _derived_opt_sharding).
        opt_sh = {"adamw": {"m": param_sh, "v": param_sh, "step": repl},
                  "sgdm": {"mom": param_sh, "step": repl},
                  }.get(self._optimizer_name)
        if opt_sh is None:
            opt_sh = self._derived_opt_sharding(param_sh, repl)
        return ((param_sh, opt_sh, batch_sh, repl, repl),
                (param_sh, opt_sh, repl, repl))

    def _record_opt_spec(self, opt):
        """Remember the optimizer-state structure so ``_step_shardings``
        can derive a layout for custom optimizer callables (the named
        table only covers adamw/sgdm).  Recorded once, from the first
        ``private_step``/``verify`` call — i.e. before the step closure
        is first jitted, so the derived shardings reach ``jax.jit``."""
        if opt is not None and self._opt_spec is None \
                and self._optimizer_name is None:
            self._opt_spec = _spec_of(opt)

    def _derived_opt_sharding(self, param_sh, repl):
        """Sharding for a custom optimizer callable's state, derived from
        its recorded state pytree: a leaf shaped like a param whose layout
        is unambiguous inherits that param's sharding (matching the
        adamw/sgdm moment treatment); scalars and ambiguous shapes stay
        replicated.  With no recorded spec the whole state is replicated
        — correct, just not partitioned."""
        if self._opt_spec is None:
            return repl
        by_shape = {}
        for leaf, sh in zip(jax.tree_util.tree_leaves(self._params_spec),
                            jax.tree_util.tree_leaves(param_sh)):
            shape = tuple(leaf.shape)
            cur = by_shape.get(shape, sh)
            by_shape[shape] = cur if cur == sh else None   # ambiguous

        def leaf_sh(leaf):
            shape = tuple(leaf.shape)
            sh = by_shape.get(shape) if shape else None
            return sh if sh is not None else repl

        return jax.tree_util.tree_map(leaf_sh, self._opt_spec)

    @functools.cached_property
    def _jit_step(self):
        step = self._step_fn()
        shardings = self._step_shardings()
        if shardings is None:
            return jax.jit(step)
        # Explicit shardings: per-example norms and the clipped sum reduce
        # globally under SPMD (flat clip coefficients see the psum'd
        # global norm; per-layer norms are psum'd the same way, per
        # group), and the noise is drawn from the one replicated key, so
        # each device adds identical noise rather than independent
        # per-shard draws.
        return jax.jit(step, in_shardings=shardings[0],
                       out_shardings=shardings[1])

    def verify(self, *, opt=None, raise_on_error: bool = False,
               coll_bytes_warn=None):
        """Statically verify this engine's private step (no execution):
        trace it to a jaxpr and check clip-before-reduce taint discipline,
        noise calibration and key hygiene, sharding invariants, and
        plan/graph consistency.  Returns a
        :class:`repro.analysis.report.VerifyReport`; with
        ``raise_on_error=True`` a failed report raises
        :class:`repro.analysis.report.DPVerificationError` instead."""
        from repro.analysis.verifier import verify_engine
        self._record_opt_spec(opt)
        report = verify_engine(self, opt=opt,
                               coll_bytes_warn=coll_bytes_warn)
        if raise_on_error:
            report.raise_if_failed()
        return report

    def private_step(self, params, opt, batch, key=None, *,
                     step: int | None = None):
        """One fused DP-SGD step: gradient + clip + noise + optimizer
        update in a single jitted closure over the plan, plus host-side
        accountant bookkeeping.  With a mesh the closure is jitted with
        explicit shardings (batch on the data axes; params, optimizer
        state, key, and outputs replicated).  Returns (params, opt, loss,
        aux).  ``step=`` (with a ``run_seed`` engine) draws the noise
        from the deterministic per-step stream instead of an explicit
        key — the restart-safe way to drive the loop.

        Non-flat clipping modes thread state across steps: ``stale``
        feeds this step's norms to the next step's coefficients (the
        first step bootstraps with exact flat clipping); ``per_layer``
        with ``budgets="auto"`` re-splits the budget from the tracked
        per-layer norm quantiles after every step."""
        self._record_opt_spec(opt)
        out = self._jit_step(params, opt, batch, self._check_key(key, step),
                             self._clip_state())
        self._absorb_clip_aux(out[3])
        if self.accountant is not None:
            self.accountant.step()
        return out

    # -- accounting --------------------------------------------------------

    def epsilon(self, delta: float | None = None) -> float:
        if self.accountant is None:
            raise ValueError("engine has no accountant; pass sampling_rate=")
        return self.accountant.epsilon(delta if delta is not None
                                       else self.dp.delta)

    def report(self, delta: float | None = None) -> str:
        if self.accountant is None:
            return "DP: no accountant attached"
        return self.accountant.report(delta if delta is not None
                                      else self.dp.delta)
