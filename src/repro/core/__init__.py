"""The paper's primary contribution: per-example gradient computation
(naive / multi / crb of Rochette et al. 2019, plus ghost & book-keeping
extensions) and the DP-SGD machinery built on it.  The plan-first
:class:`PrivacyEngine` is the public entry point; the strategy-level
functions remain as its functional core and compatibility surface."""
from repro.core.clipping import (ClipPolicy, DPConfig, NormCfg, add_noise,
                                 dp_gradient, non_dp_gradient,
                                 resolve_budgets, resolve_microbatches)
from repro.core.costmodel import (ExecPlan, check_plan_matches,
                                  code_fingerprint, mesh_axes,
                                  plan_fingerprint)
from repro.core.engine import KeyProvenanceError, PrivacyEngine
from repro.core.privacy import (PrivacyAccountant, clipping_sensitivity,
                                rdp_subsampled_gaussian)
from repro.core.strategies import (STRATEGIES, check_coverage,
                                   clip_coefficients, clipped_grad_sum,
                                   clipped_grad_sum_detailed,
                                   crb_per_example_grads, ghost_norms,
                                   multi_per_example_grads,
                                   naive_per_example_grads,
                                   per_example_grads,
                                   per_layer_clip_coefficients)
from repro.core.tapper import (LayerMeta, Tapper, capture_backward, probe,
                               scan_with_taps)

__all__ = [
    "ClipPolicy", "DPConfig", "NormCfg", "ExecPlan", "KeyProvenanceError",
    "PrivacyEngine", "code_fingerprint",
    "add_noise", "dp_gradient", "non_dp_gradient", "resolve_budgets",
    "resolve_microbatches", "PrivacyAccountant", "clipping_sensitivity",
    "rdp_subsampled_gaussian", "STRATEGIES", "check_coverage",
    "clip_coefficients", "clipped_grad_sum", "clipped_grad_sum_detailed",
    "crb_per_example_grads", "ghost_norms", "multi_per_example_grads",
    "naive_per_example_grads", "per_example_grads",
    "per_layer_clip_coefficients", "LayerMeta", "Tapper",
    "capture_backward", "probe", "scan_with_taps",
]
