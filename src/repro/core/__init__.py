"""The paper's primary contribution: per-example gradient computation
(naive / multi / crb of Rochette et al. 2019, plus ghost & book-keeping
extensions) and the DP-SGD machinery built on it.  The plan-first
:class:`PrivacyEngine` is the public entry point; the strategy-level
functions remain as its functional core and compatibility surface."""
from repro.core.clipping import (DPConfig, NormCfg, add_noise, dp_gradient,
                                 non_dp_gradient, resolve_microbatches)
from repro.core.costmodel import (ExecPlan, check_plan_matches, mesh_axes,
                                  plan_fingerprint)
from repro.core.engine import PrivacyEngine
from repro.core.privacy import PrivacyAccountant, rdp_subsampled_gaussian
from repro.core.strategies import (STRATEGIES, check_coverage,
                                   clip_coefficients, clipped_grad_sum,
                                   crb_per_example_grads, ghost_norms,
                                   multi_per_example_grads,
                                   naive_per_example_grads, per_example_grads)
from repro.core.tapper import (LayerMeta, Tapper, capture_backward, probe,
                               scan_with_taps)

__all__ = [
    "DPConfig", "NormCfg", "ExecPlan", "PrivacyEngine", "add_noise",
    "dp_gradient", "non_dp_gradient", "resolve_microbatches",
    "PrivacyAccountant", "rdp_subsampled_gaussian", "STRATEGIES",
    "check_coverage", "clip_coefficients", "clipped_grad_sum",
    "crb_per_example_grads", "ghost_norms", "multi_per_example_grads",
    "naive_per_example_grads", "per_example_grads", "LayerMeta", "Tapper",
    "capture_backward", "probe", "scan_with_taps",
]
