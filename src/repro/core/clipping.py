"""DP-SGD gradient computation: clip, accumulate, noise.

The preferred entry point is :class:`repro.core.engine.PrivacyEngine`
(make-private-once, step-many); :func:`dp_gradient` remains as the
functional core the engine drives and as a thin compatibility shim for
pre-engine callers.

Distribution notes (pjit): the pipeline is written in the global view, so
under :class:`~repro.core.engine.PrivacyEngine`'s sharded ``private_step``
(batch sharded over the data axes, params replicated) XLA partitions it
automatically — per-example norms are computed on the shard holding the
example and the clip coefficients see the psum'd global norm; the clipped
gradient sum is all-reduced over the data axis like any gradient.  Noise
is generated from the one replicated key against the replicated gradient,
so every device adds the *same* draw — not independent per-shard noise
(which would inflate the variance by the shard count).  With params
partitioned over a model axis the *noise array itself* is sharded, which
is why this module pins the partitionable threefry implementation below:
every draw must be a pure function of (key, position), identical under
any layout.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Mapping

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import costmodel, strategies

# Legacy (non-partitionable) threefry generates different bits when XLA
# partitions a draw: a model-sharded noise array would silently differ
# from the single-device draw for the same key, breaking both the
# sharded == single-device equivalence and noise-replay across topology
# changes (elastic resume).  The partitionable implementation makes
# every draw a pure function of (key, position) — identical values under
# any sharding — so it is a correctness requirement here, not a tuning
# flag.
jax.config.update("jax_threefry_partitionable", True)

CLIP_MODES = ("flat", "per_layer", "stale")


@dataclasses.dataclass(frozen=True)
class ClipPolicy:
    """How per-example clip coefficients are derived and applied.

    Modes:
      * ``flat``      — one coefficient per example from the *total* grad
        norm: ``w_b = min(1, C / ‖g_b‖)``.  Today's default; exact.
      * ``per_layer`` — per-layer budgets ``C_l`` with ``Σ_l C_l² = C²``;
        each parameter group is clipped against its own norm,
        ``w_{l,b} = min(1, C_l / ‖g_{l,b}‖)``.  The clipped sum's L2
        sensitivity is still ``C`` (see
        :func:`repro.core.privacy.clipping_sensitivity`), so the noise
        calibration is unchanged.  A layer's coefficient depends only on
        its own norm — no cross-layer reduction — and the planner drops
        the shared weighted backward (it cannot realize per-layer
        weights in one backward).
      * ``stale``     — flat coefficients computed from the *previous*
        step's norms.  The norm → coefficient dependency disappears from
        inside the step, so every layer's norm and weighted contribution
        can be produced in a single pass over the captures — the fused
        ``gram_norm_fused`` Pallas path — and a steady-state step is
        exactly 1 forward + 1 backward with no phase barrier.  Exactness
        caveat: this step's contribution is bounded by ``C`` only under
        the *lagged* norms; the first engine step bootstraps with exact
        flat clipping.

    ``budgets`` (``per_layer`` only): ``"uniform"`` (``C_l = C/√L``),
    ``"auto"`` (the engine tracks per-layer norm quantiles host-side and
    re-splits every step), or a mapping of {group-key glob: relative
    weight} (first match wins, unmatched groups get weight 1; weights are
    normalized so ``Σ C_l² = C²``).  Group keys are ``"/"``-joined
    parameter paths (e.g. ``"blocks/fc"``).

    ``fused`` (``stale`` only): allow the planner to select the fused
    single-pass norm+contrib realizations.  ``fused=False`` forces the
    same realizations flat mode uses, making a stale step *bitwise*
    reproducible against a flat step fed the same norms (the oracle
    suite relies on this).

    ``quantile`` / ``ema``: the per-layer norm statistic and host-side
    decay driving ``budgets="auto"``.
    """

    mode: str = "flat"
    budgets: Any = "uniform"
    fused: bool = True
    quantile: float = 0.5
    ema: float = 0.9

    def __post_init__(self):
        if self.mode not in CLIP_MODES:
            raise ValueError(f"unknown clipping mode {self.mode!r}; "
                             f"choose from {CLIP_MODES}")
        if isinstance(self.budgets, str):
            if self.budgets not in ("uniform", "auto"):
                raise ValueError(
                    f"budgets must be 'uniform', 'auto', or a "
                    f"{{glob: weight}} mapping, got {self.budgets!r}")
        else:
            object.__setattr__(self, "budgets", tuple(
                (str(p), float(w)) for p, w in
                (self.budgets.items() if isinstance(self.budgets, Mapping)
                 else self.budgets)))

def as_clip_policy(clipping) -> ClipPolicy:
    if clipping is None:
        return ClipPolicy()
    if isinstance(clipping, ClipPolicy):
        return clipping
    if isinstance(clipping, str):
        return ClipPolicy(mode=clipping)
    raise TypeError(f"clipping must be a ClipPolicy or mode string, "
                    f"got {clipping!r}")


def resolve_budgets(policy: ClipPolicy, l2_clip: float, group_keys,
                    observed=None):
    """Per-group clip budgets ``C_l`` with ``Σ_l C_l² = C²`` (exactly, up
    to float rounding — property-tested).

    ``observed`` (per-group positive norm statistics, e.g. the engine's
    tracked quantiles) drives the ``"auto"`` split ``C_l ∝ q_l``; without
    it ``"auto"`` falls back to uniform.  Mapping budgets are glob-matched
    against the ``"/"``-joined group keys, first match wins.
    """
    from fnmatch import fnmatchcase
    G = len(group_keys)
    if G == 0:
        raise ValueError("no parameter groups to budget")
    if isinstance(policy.budgets, tuple):
        w = []
        for key in group_keys:
            for pat, wt in policy.budgets:
                if fnmatchcase(key, pat):
                    w.append(wt)
                    break
            else:
                w.append(1.0)
        w = np.asarray(w, np.float64)
    elif policy.budgets == "auto" and observed is not None:
        w = np.asarray(observed, np.float64)
    else:
        w = np.ones((G,), np.float64)
    w = np.maximum(w, 1e-12)
    b = l2_clip * w / np.sqrt(np.sum(w * w))
    return jnp.asarray(b, jnp.float32)


@dataclasses.dataclass(frozen=True)
class NormCfg:
    """Per-kind norm-realization knobs (all default to the planner's
    analytic choice).

    dense:     auto | gram | stream | rank1 | pallas
    embed:     auto | segsum | gram | pe
    conv:      auto | ghost | pe          (norm realization)
    conv_impl: fgc | bgc | pallas         (materializing conv-grad impl)
    mem_budget: bytes of per-example-grad / capture scratch tolerated —
        bounds the planner's materializing paths AND drives
        ``microbatches="auto"``.
    """

    dense: str = "auto"
    embed: str = "auto"
    conv: str = "auto"
    conv_impl: str = "fgc"
    mem_budget: int = costmodel.STREAM_MEM_BUDGET


# Legacy-kwarg sentinel: distinguishes "caller did not pass conv_norm" from
# the historical conv_norm=None, which is itself deprecated (now = "auto").
_UNSET = object()


@dataclasses.dataclass(frozen=True, init=False)
class DPConfig:
    """Structured DP-SGD configuration.

    Replaces the seed-era string soup (norm_method / embed_norm / conv_impl
    / conv_norm threaded positionally): norm realizations live in a nested
    :class:`NormCfg`, and individual layers are pinned with ``overrides``
    ({tap-name glob: method}, first match wins).  ``microbatches`` may be
    ``"auto"``: the count is derived from the ExecPlan's per-layer
    peak-memory estimates against ``norm.mem_budget``.

    The legacy keyword arguments are still accepted (with a
    DeprecationWarning) and mapped onto ``norm``; the historical
    ``conv_norm=None`` sentinel is gone — it now means ``"auto"``, and the
    old ghost/bk materialize-always behaviour is an explicit
    ``NormCfg(conv="pe")`` away.
    """

    l2_clip: float = 1.0
    noise_multiplier: float = 0.0
    strategy: str = "auto"           # naive | multi | crb | ghost | bk | auto
    norm: NormCfg = NormCfg()
    overrides: tuple = ()            # ((tap-name glob, method), ...)
    microbatches: Any = 1            # int or "auto"
    delta: float = 1e-5
    clipping: ClipPolicy = ClipPolicy()

    def __init__(self, l2_clip: float = 1.0, noise_multiplier: float = 0.0,
                 strategy: str = "auto", norm: NormCfg | None = None,
                 overrides=(), microbatches: Any = 1, delta: float = 1e-5,
                 clipping: ClipPolicy | str | None = None,
                 *, norm_method: str | None = None,
                 embed_norm: str | None = None, conv_impl: str | None = None,
                 conv_norm: Any = _UNSET):
        norm = norm or NormCfg()
        clipping = as_clip_policy(clipping)
        if clipping.mode != "flat" and strategy not in ("auto", "bk"):
            raise ValueError(
                f"clipping mode {clipping.mode!r} requires strategy 'auto' "
                f"or 'bk' (got {strategy!r}): the ghost weighted backward "
                f"and the materializing strategies only realize one flat "
                f"coefficient per example")
        legacy = {"norm_method": norm_method, "embed_norm": embed_norm,
                  "conv_impl": conv_impl}
        if conv_norm is not _UNSET:
            legacy["conv_norm"] = conv_norm
        if any(v is not None for v in legacy.values()) \
                or conv_norm is not _UNSET:
            warnings.warn(
                "DPConfig(norm_method=/embed_norm=/conv_impl=/conv_norm=) "
                "is deprecated; use DPConfig(norm=NormCfg(...)) and "
                "overrides={...} (conv_norm=None now means 'auto')",
                DeprecationWarning, stacklevel=2)
            norm = dataclasses.replace(
                norm,
                dense=norm_method or norm.dense,
                embed=embed_norm or norm.embed,
                conv_impl=conv_impl or norm.conv_impl,
                conv=(norm.conv if conv_norm is _UNSET
                      else (conv_norm or "auto")))
        if not (microbatches == "auto"
                or (isinstance(microbatches, int) and microbatches >= 1)):
            raise ValueError(
                f"microbatches must be a positive int or 'auto', "
                f"got {microbatches!r}")
        object.__setattr__(self, "l2_clip", float(l2_clip))
        object.__setattr__(self, "noise_multiplier", float(noise_multiplier))
        object.__setattr__(self, "strategy", strategy)
        object.__setattr__(self, "norm", norm)
        object.__setattr__(self, "overrides",
                           costmodel.normalize_overrides(overrides))
        object.__setattr__(self, "microbatches", microbatches)
        object.__setattr__(self, "delta", float(delta))
        object.__setattr__(self, "clipping", clipping)

    # Read-only views under the old knob names, so pre-engine call sites
    # keep working during the migration.
    @property
    def norm_method(self) -> str:
        return self.norm.dense

    @property
    def embed_norm(self) -> str:
        return self.norm.embed

    @property
    def conv_impl(self) -> str:
        return self.norm.conv_impl

    @property
    def conv_norm(self) -> str:
        return self.norm.conv

    def planner_opts(self) -> dict:
        """Keyword arguments for :func:`repro.core.costmodel.get_plan`."""
        return dict(norm_method=self.norm.dense, embed_method=self.norm.embed,
                    conv_norm=self.norm.conv, mem_budget=self.norm.mem_budget,
                    overrides=self.overrides,
                    clip_mode=self.clipping.mode,
                    clip_fused=self.clipping.fused)


def add_noise(grad_sum, key, noise_multiplier: float, l2_clip: float):
    """Add N(0, (σC)²) noise per coordinate.  Noise is generated *and
    summed* in float32 — only the final result is cast back to the grad
    dtype, so low-precision (bf16) grads don't silently quantize the noise
    before it is applied."""
    if noise_multiplier == 0.0:
        return grad_sum
    from repro.analysis.markers import tag
    leaves, treedef = jax.tree.flatten(grad_sum)
    keys = jax.random.split(key, len(leaves))
    sigma = noise_multiplier * l2_clip
    noisy = []
    for g, k in zip(leaves, keys):
        noise = tag(sigma * jax.random.normal(k, g.shape, jnp.float32),
                    kind="noise", sigma=float(sigma),
                    noise_multiplier=float(noise_multiplier),
                    l2_clip=float(l2_clip))
        noisy.append((g.astype(jnp.float32) + noise).astype(g.dtype))
    return jax.tree.unflatten(treedef, noisy)


def resolve_microbatches(apply_fn, params, batch, cfg: DPConfig,
                         plan=None, mesh=None) -> int:
    """Resolve ``cfg.microbatches`` to a concrete count.  ``"auto"`` derives
    it from the full-batch ExecPlan's memory estimates (planned strategies
    only; fixed strategies have no plan to consult and run unsplit).
    ``mesh`` makes the consulted plan's estimates per-device, so the split
    is sized for a device's batch shard rather than the global batch."""
    m = cfg.microbatches
    if m != "auto":
        return int(m)
    if cfg.strategy != "auto":
        return 1
    if plan is None:
        plan = costmodel.get_plan(apply_fn, params, batch,
                                  mesh=mesh, **cfg.planner_opts())
    B = jax.tree.leaves(batch)[0].shape[0]
    return costmodel.auto_microbatches(plan, B, cfg.norm.mem_budget)


def dp_gradient(apply_fn: Callable, params, batch, *, cfg: DPConfig,
                key=None, denom: int | None = None, plan=None,
                clip_state: dict | None = None):
    """Full DP-SGD gradient:  (Σ_b clip(g_b) + σC·ξ) / denom.

    ``batch`` leaves have leading global batch B; with ``cfg.microbatches``
    > 1 the batch is split and scanned to bound activation memory (valid
    because clipping is per-example and accumulation a plain sum).
    ``microbatches="auto"`` derives the split from the ExecPlan's memory
    estimates.  ``plan`` injects a pre-built (possibly deserialized)
    ExecPlan; it must match the per-microbatch shapes *and* the clipping
    mode.

    ``clip_state`` threads the cross-step clipping state of non-flat
    :class:`ClipPolicy` modes (the engine owns this loop):
      * ``{"prev_norms_sq": (B,)}`` — ``stale``: the norms the lagged
        coefficients are computed from.  Absent → bootstrap: this call
        clips with exact flat coefficients (and a flat plan) and returns
        the norms to feed the next step.
      * ``{"budgets": (G,)}`` — ``per_layer`` with ``budgets="auto"``:
        the engine-tracked split.  Absent → the policy's static split
        (uniform / mapping) is resolved against the plan's groups.

    Returns (mean loss, gradient pytree, aux dict).  Mode-dependent aux:
    ``per_layer`` adds ``per_layer_norms`` (G, B), ``per_layer_clip_
    fraction`` (G,) and ``clip_budgets``; ``stale`` adds ``clip_fraction_
    lagged`` (the coefficients actually *applied* this step — the plain
    ``clip_fraction`` describes the current norms, i.e. next step's
    coefficients) and ``clip_state`` for threading.
    """
    B = jax.tree.leaves(batch)[0].shape[0]
    denom = denom or B
    policy = cfg.clipping
    clip_state = dict(clip_state or {})
    prev_ns = clip_state.get("prev_norms_sq")
    budgets = clip_state.get("budgets")
    bootstrap = policy.mode == "stale" and prev_ns is None
    if bootstrap:
        # No lagged norms yet: clip exactly (flat), under a flat plan —
        # the stale plan's fused realizations need coefficients entering
        # the pass.  The returned clip_state seeds the steady state.
        policy = ClipPolicy()
        cfg = dataclasses.replace(cfg, clipping=policy)
        plan = None
    m = cfg.microbatches
    if m == "auto":
        m = resolve_microbatches(apply_fn, params, batch, cfg, plan=plan)
        if m > 1:
            plan = None   # a caller-supplied plan was for the full batch

    def one_microbatch(mb, mb_plan, mb_prev_ns):
        losses, gsum, norms_sq, detail = strategies.clipped_grad_sum_detailed(
            apply_fn, params, mb, l2_clip=cfg.l2_clip, strategy=cfg.strategy,
            norm_method=cfg.norm.dense, conv_impl=cfg.norm.conv_impl,
            embed_method=cfg.norm.embed, conv_norm=cfg.norm.conv,
            overrides=cfg.overrides, mem_budget=cfg.norm.mem_budget,
            plan=mb_plan, clip_policy=policy, budgets=budgets,
            prev_norms_sq=mb_prev_ns)
        return losses, jax.tree.map(lambda g: g.astype(jnp.float32), gsum), \
            norms_sq, detail["group_norms_sq"], detail["budgets"]

    if m == 1:
        losses, gsum, norms_sq, group_ns, budgets_used = \
            one_microbatch(batch, plan, prev_ns)
    else:
        assert B % m == 0, f"batch {B} not divisible by microbatches {m}"
        mbs = jax.tree.map(lambda a: a.reshape((m, B // m) + a.shape[1:]),
                           batch)
        prev_mbs = (None if prev_ns is None
                    else prev_ns.reshape(m, B // m))

        def body(acc, xs):
            mb, mb_prev = xs
            losses, gsum, norms_sq, group_ns, bud = \
                one_microbatch(mb, plan, mb_prev)
            acc = jax.tree.map(jnp.add, acc, gsum)
            return acc, (losses, norms_sq, group_ns, bud)

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, (losses, norms_sq, group_ns, buds) = jax.lax.scan(
            body, zeros, (mbs, prev_mbs))
        losses = losses.reshape(-1)
        norms_sq = norms_sq.reshape(-1)
        if group_ns is not None:
            # (m, G, B/m) -> (G, B): microbatches tile the example axis.
            group_ns = jnp.moveaxis(group_ns, 0, 1).reshape(
                group_ns.shape[1], -1)
        budgets_used = (None if buds is None
                        else jax.tree.map(lambda a: a[0], buds))

    if key is not None and cfg.noise_multiplier > 0:
        gsum = add_noise(gsum, key, cfg.noise_multiplier, cfg.l2_clip)
    grad = jax.tree.map(lambda g: g / denom, gsum)
    C = cfg.l2_clip
    aux = {
        "per_example_norms": jnp.sqrt(norms_sq + 1e-12),
        "clip_fraction": jnp.mean(
            (jnp.sqrt(norms_sq) > C).astype(jnp.float32)),
    }
    if policy.mode == "per_layer":
        # The flat-style scalar above would be silently wrong (it compares
        # the *total* norm against C while clipping happened per layer):
        # report per-layer fractions against the per-layer budgets, and
        # make the scalar their mean over (layer, example) pairs.
        clipped = (jnp.sqrt(group_ns + 1e-12)
                   > budgets_used[:, None]).astype(jnp.float32)
        aux["per_layer_norms"] = jnp.sqrt(group_ns + 1e-12)
        aux["per_layer_clip_fraction"] = jnp.mean(clipped, axis=1)
        aux["clip_fraction"] = jnp.mean(clipped)
        aux["clip_budgets"] = budgets_used
    elif policy.mode == "stale" or bootstrap:
        # ``clip_fraction`` above describes the *current* norms — the
        # coefficients the next step will apply.  What this step actually
        # applied is lagged; label it instead of reporting it wrongly.
        applied_ns = norms_sq if bootstrap else prev_ns
        aux["clip_fraction_lagged"] = jnp.mean(
            (jnp.sqrt(applied_ns) > C).astype(jnp.float32))
        aux["clip_state"] = {"prev_norms_sq": norms_sq}
    return jnp.mean(losses), grad, aux


def non_dp_gradient(apply_fn: Callable, params, batch):
    """Reference non-private gradient (mean loss) for overhead baselines."""
    from repro.core.tapper import Tapper

    def loss(p):
        return jnp.mean(apply_fn(p, batch, Tapper()))

    return jax.value_and_grad(loss)(params)
