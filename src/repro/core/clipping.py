"""DP-SGD gradient computation: clip, accumulate, noise.

The preferred entry point is :class:`repro.core.engine.PrivacyEngine`
(make-private-once, step-many); :func:`dp_gradient` remains as the
functional core the engine drives and as a thin compatibility shim for
pre-engine callers.

Distribution notes (pjit): the pipeline is written in the global view, so
under :class:`~repro.core.engine.PrivacyEngine`'s sharded ``private_step``
(batch sharded over the data axes, params replicated) XLA partitions it
automatically — per-example norms are computed on the shard holding the
example and the clip coefficients see the psum'd global norm; the clipped
gradient sum is all-reduced over the data axis like any gradient.  Noise
is generated from the one replicated key against the replicated gradient,
so every device adds the *same* draw — not independent per-shard noise
(which would inflate the variance by the shard count).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import costmodel, strategies


@dataclasses.dataclass(frozen=True)
class NormCfg:
    """Per-kind norm-realization knobs (all default to the planner's
    analytic choice).

    dense:     auto | gram | stream | rank1 | pallas
    embed:     auto | segsum | gram | pe
    conv:      auto | ghost | pe          (norm realization)
    conv_impl: fgc | bgc | pallas         (materializing conv-grad impl)
    mem_budget: bytes of per-example-grad / capture scratch tolerated —
        bounds the planner's materializing paths AND drives
        ``microbatches="auto"``.
    """

    dense: str = "auto"
    embed: str = "auto"
    conv: str = "auto"
    conv_impl: str = "fgc"
    mem_budget: int = costmodel.STREAM_MEM_BUDGET


# Legacy-kwarg sentinel: distinguishes "caller did not pass conv_norm" from
# the historical conv_norm=None, which is itself deprecated (now = "auto").
_UNSET = object()


@dataclasses.dataclass(frozen=True, init=False)
class DPConfig:
    """Structured DP-SGD configuration.

    Replaces the seed-era string soup (norm_method / embed_norm / conv_impl
    / conv_norm threaded positionally): norm realizations live in a nested
    :class:`NormCfg`, and individual layers are pinned with ``overrides``
    ({tap-name glob: method}, first match wins).  ``microbatches`` may be
    ``"auto"``: the count is derived from the ExecPlan's per-layer
    peak-memory estimates against ``norm.mem_budget``.

    The legacy keyword arguments are still accepted (with a
    DeprecationWarning) and mapped onto ``norm``; the historical
    ``conv_norm=None`` sentinel is gone — it now means ``"auto"``, and the
    old ghost/bk materialize-always behaviour is an explicit
    ``NormCfg(conv="pe")`` away.
    """

    l2_clip: float = 1.0
    noise_multiplier: float = 0.0
    strategy: str = "auto"           # naive | multi | crb | ghost | bk | auto
    norm: NormCfg = NormCfg()
    overrides: tuple = ()            # ((tap-name glob, method), ...)
    microbatches: Any = 1            # int or "auto"
    delta: float = 1e-5

    def __init__(self, l2_clip: float = 1.0, noise_multiplier: float = 0.0,
                 strategy: str = "auto", norm: NormCfg | None = None,
                 overrides=(), microbatches: Any = 1, delta: float = 1e-5,
                 *, norm_method: str | None = None,
                 embed_norm: str | None = None, conv_impl: str | None = None,
                 conv_norm: Any = _UNSET):
        norm = norm or NormCfg()
        legacy = {"norm_method": norm_method, "embed_norm": embed_norm,
                  "conv_impl": conv_impl}
        if conv_norm is not _UNSET:
            legacy["conv_norm"] = conv_norm
        if any(v is not None for v in legacy.values()) \
                or conv_norm is not _UNSET:
            warnings.warn(
                "DPConfig(norm_method=/embed_norm=/conv_impl=/conv_norm=) "
                "is deprecated; use DPConfig(norm=NormCfg(...)) and "
                "overrides={...} (conv_norm=None now means 'auto')",
                DeprecationWarning, stacklevel=2)
            norm = dataclasses.replace(
                norm,
                dense=norm_method or norm.dense,
                embed=embed_norm or norm.embed,
                conv_impl=conv_impl or norm.conv_impl,
                conv=(norm.conv if conv_norm is _UNSET
                      else (conv_norm or "auto")))
        if not (microbatches == "auto"
                or (isinstance(microbatches, int) and microbatches >= 1)):
            raise ValueError(
                f"microbatches must be a positive int or 'auto', "
                f"got {microbatches!r}")
        object.__setattr__(self, "l2_clip", float(l2_clip))
        object.__setattr__(self, "noise_multiplier", float(noise_multiplier))
        object.__setattr__(self, "strategy", strategy)
        object.__setattr__(self, "norm", norm)
        object.__setattr__(self, "overrides",
                           costmodel.normalize_overrides(overrides))
        object.__setattr__(self, "microbatches", microbatches)
        object.__setattr__(self, "delta", float(delta))

    # Read-only views under the old knob names, so pre-engine call sites
    # keep working during the migration.
    @property
    def norm_method(self) -> str:
        return self.norm.dense

    @property
    def embed_norm(self) -> str:
        return self.norm.embed

    @property
    def conv_impl(self) -> str:
        return self.norm.conv_impl

    @property
    def conv_norm(self) -> str:
        return self.norm.conv

    def planner_opts(self) -> dict:
        """Keyword arguments for :func:`repro.core.costmodel.get_plan`."""
        return dict(norm_method=self.norm.dense, embed_method=self.norm.embed,
                    conv_norm=self.norm.conv, mem_budget=self.norm.mem_budget,
                    overrides=self.overrides)


def add_noise(grad_sum, key, noise_multiplier: float, l2_clip: float):
    """Add N(0, (σC)²) noise per coordinate.  Noise is generated *and
    summed* in float32 — only the final result is cast back to the grad
    dtype, so low-precision (bf16) grads don't silently quantize the noise
    before it is applied."""
    if noise_multiplier == 0.0:
        return grad_sum
    leaves, treedef = jax.tree.flatten(grad_sum)
    keys = jax.random.split(key, len(leaves))
    sigma = noise_multiplier * l2_clip
    noisy = [
        (g.astype(jnp.float32)
         + sigma * jax.random.normal(k, g.shape, jnp.float32)).astype(g.dtype)
        for g, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noisy)


def resolve_microbatches(apply_fn, params, batch, cfg: DPConfig,
                         plan=None, mesh=None) -> int:
    """Resolve ``cfg.microbatches`` to a concrete count.  ``"auto"`` derives
    it from the full-batch ExecPlan's memory estimates (planned strategies
    only; fixed strategies have no plan to consult and run unsplit).
    ``mesh`` makes the consulted plan's estimates per-device, so the split
    is sized for a device's batch shard rather than the global batch."""
    m = cfg.microbatches
    if m != "auto":
        return int(m)
    if cfg.strategy != "auto":
        return 1
    if plan is None:
        plan = costmodel.get_plan(apply_fn, params, batch,
                                  mesh=mesh, **cfg.planner_opts())
    B = jax.tree.leaves(batch)[0].shape[0]
    return costmodel.auto_microbatches(plan, B, cfg.norm.mem_budget)


def dp_gradient(apply_fn: Callable, params, batch, *, cfg: DPConfig,
                key=None, denom: int | None = None, plan=None):
    """Full DP-SGD gradient:  (Σ_b clip_C(g_b) + σC·ξ) / denom.

    ``batch`` leaves have leading global batch B; with ``cfg.microbatches``
    > 1 the batch is split and scanned to bound activation memory (valid
    because clipping is per-example and accumulation a plain sum).
    ``microbatches="auto"`` derives the split from the ExecPlan's memory
    estimates.  ``plan`` injects a pre-built (possibly deserialized)
    ExecPlan; it must match the per-microbatch shapes.

    Returns (mean loss, gradient pytree, aux dict).
    """
    B = jax.tree.leaves(batch)[0].shape[0]
    denom = denom or B
    m = cfg.microbatches
    if m == "auto":
        m = resolve_microbatches(apply_fn, params, batch, cfg, plan=plan)
        if m > 1:
            plan = None   # a caller-supplied plan was for the full batch

    def one_microbatch(mb, mb_plan):
        losses, gsum, norms_sq = strategies.clipped_grad_sum(
            apply_fn, params, mb, l2_clip=cfg.l2_clip, strategy=cfg.strategy,
            norm_method=cfg.norm.dense, conv_impl=cfg.norm.conv_impl,
            embed_method=cfg.norm.embed, conv_norm=cfg.norm.conv,
            overrides=cfg.overrides, mem_budget=cfg.norm.mem_budget,
            plan=mb_plan)
        return losses, jax.tree.map(lambda g: g.astype(jnp.float32), gsum), \
            norms_sq

    if m == 1:
        losses, gsum, norms_sq = one_microbatch(batch, plan)
    else:
        assert B % m == 0, f"batch {B} not divisible by microbatches {m}"
        mbs = jax.tree.map(lambda a: a.reshape((m, B // m) + a.shape[1:]),
                           batch)

        def body(acc, mb):
            losses, gsum, norms_sq = one_microbatch(mb, plan)
            acc = jax.tree.map(jnp.add, acc, gsum)
            return acc, (losses, norms_sq)

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, (losses, norms_sq) = jax.lax.scan(body, zeros, mbs)
        losses = losses.reshape(-1)
        norms_sq = norms_sq.reshape(-1)

    if key is not None and cfg.noise_multiplier > 0:
        gsum = add_noise(gsum, key, cfg.noise_multiplier, cfg.l2_clip)
    grad = jax.tree.map(lambda g: g / denom, gsum)
    aux = {
        "per_example_norms": jnp.sqrt(norms_sq + 1e-12),
        "clip_fraction": jnp.mean(
            (jnp.sqrt(norms_sq) > cfg.l2_clip).astype(jnp.float32)),
    }
    return jnp.mean(losses), grad, aux


def non_dp_gradient(apply_fn: Callable, params, batch):
    """Reference non-private gradient (mean loss) for overhead baselines."""
    from repro.core.tapper import Tapper

    def loss(p):
        return jnp.mean(apply_fn(p, batch, Tapper()))

    return jax.value_and_grad(loss)(params)
