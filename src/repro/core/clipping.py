"""DP-SGD gradient computation: clip, accumulate, noise.

Distribution notes (pjit): per-example norms are computed from sharded
captures — XLA inserts the (B,)-sized reductions over the tensor-parallel
axis automatically; the clipped gradient sum is reduced over the data axis
like any gradient.  Noise is generated with a partitionable threefry key,
so each device materializes only its shard of the noise tensor.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import strategies


@dataclasses.dataclass(frozen=True)
class DPConfig:
    l2_clip: float = 1.0
    noise_multiplier: float = 0.0
    strategy: str = "ghost"          # naive | multi | crb | ghost | bk | auto
    norm_method: str = "auto"        # auto | gram | stream | pallas
    embed_norm: str = "auto"         # auto | segsum | gram | pe
    conv_impl: str = "fgc"           # fgc | bgc | pallas
    conv_norm: str | None = None     # auto | ghost | pe (None = historical)
    microbatches: int = 1
    delta: float = 1e-5


def add_noise(grad_sum, key, noise_multiplier: float, l2_clip: float):
    if noise_multiplier == 0.0:
        return grad_sum
    leaves, treedef = jax.tree.flatten(grad_sum)
    keys = jax.random.split(key, len(leaves))
    sigma = noise_multiplier * l2_clip
    noisy = [
        g + sigma * jax.random.normal(k, g.shape, jnp.float32).astype(g.dtype)
        for g, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noisy)


def dp_gradient(apply_fn: Callable, params, batch, *, cfg: DPConfig,
                key=None, denom: int | None = None):
    """Full DP-SGD gradient:  (Σ_b clip_C(g_b) + σC·ξ) / denom.

    ``batch`` leaves have leading global batch B; with ``cfg.microbatches``
    > 1 the batch is split and scanned to bound activation memory (valid
    because clipping is per-example and accumulation a plain sum).

    Returns (mean loss, gradient pytree, aux dict).
    """
    B = jax.tree.leaves(batch)[0].shape[0]
    denom = denom or B
    m = cfg.microbatches

    def one_microbatch(mb):
        losses, gsum, norms_sq = strategies.clipped_grad_sum(
            apply_fn, params, mb, l2_clip=cfg.l2_clip, strategy=cfg.strategy,
            norm_method=cfg.norm_method, conv_impl=cfg.conv_impl,
            embed_method=cfg.embed_norm, conv_norm=cfg.conv_norm)
        return losses, jax.tree.map(lambda g: g.astype(jnp.float32), gsum), \
            norms_sq

    if m == 1:
        losses, gsum, norms_sq = one_microbatch(batch)
    else:
        assert B % m == 0, f"batch {B} not divisible by microbatches {m}"
        mbs = jax.tree.map(lambda a: a.reshape((m, B // m) + a.shape[1:]),
                           batch)

        def body(acc, mb):
            losses, gsum, norms_sq = one_microbatch(mb)
            acc = jax.tree.map(jnp.add, acc, gsum)
            return acc, (losses, norms_sq)

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, (losses, norms_sq) = jax.lax.scan(body, zeros, mbs)
        losses = losses.reshape(-1)
        norms_sq = norms_sq.reshape(-1)

    if key is not None and cfg.noise_multiplier > 0:
        gsum = add_noise(gsum, key, cfg.noise_multiplier, cfg.l2_clip)
    grad = jax.tree.map(lambda g: g / denom, gsum)
    aux = {
        "per_example_norms": jnp.sqrt(norms_sq + 1e-12),
        "clip_fraction": jnp.mean(
            (jnp.sqrt(norms_sq) > cfg.l2_clip).astype(jnp.float32)),
    }
    return jnp.mean(losses), grad, aux


def non_dp_gradient(apply_fn: Callable, params, batch):
    """Reference non-private gradient (mean loss) for overhead baselines."""
    from repro.core.tapper import Tapper

    def loss(p):
        return jnp.mean(apply_fn(p, batch, Tapper()))

    return jax.value_and_grad(loss)(params)
