"""Per-layer-kind gradient algebra.

Given a layer's captured input ``x_b`` and output cotangent ``δy_b`` (from
:mod:`repro.core.tapper`), each *kind* knows three operations:

  * ``pe_grad``  — materialize per-example gradients (B, *param)  [crb]
  * ``norm_sq``  — per-example squared grad norms (B,) without
                   materialization where structure allows               [ghost]
  * ``contrib``  — weighted sum Σ_b w_b g_b at parameter shape          [bk]

For a dense layer with a sequence axis the ghost norm uses the Gram
identity  ``‖g_b‖² = Σ_{t,t'} (x_t·x_{t'}) (δy_t·δy_{t'})``  which costs
``T²(Din+Dout)`` instead of materializing ``T·Din·Dout`` — the analytic
generalization of the paper's empirical crb-vs-multi crossover.  The
choice between the two is made by :mod:`repro.core.costmodel`.

All reductions accumulate in float32 regardless of capture dtype.

Tensor parallelism (2D data x model meshes) needs **no algebra change**
here: the kinds are written in the global view, and when the engine
partitions a layer's params over the ``model`` axis (out-features for
dense, out-channels for conv, vocab rows for embed), GSPMD shards the
same contractions — each device's Gram/ghost contraction runs over its
local out-feature slice, and because ``‖g_b‖²`` is a sum over
out-features the per-example norms XLA assembles are exactly the psum
of the partial-Gram terms.  ``contrib``'s weighted sums shard the same
way (each shard owns its slice of the clipped sum).  The per-axis
collective cost of those psums is priced by
:mod:`repro.core.costmodel` (``LayerPlan.coll_bytes_by_axis``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.markers import tag
from repro.core import costmodel
from repro.core.tapper import (STATS, TAP_KEY, LayerMeta, Tapper,
                               get_subtree, set_subtree)

F32 = jnp.float32


def _realized(n, meta: LayerMeta, method: str):
    """Mark a realized per-example norm so the static verifier can
    cross-check the executed realization against the ExecPlan."""
    return tag(n, kind="realization", layer_kind=meta.kind, method=method,
               path="/".join(str(p) for p in meta.path))


def _fused_marker(n, meta: LayerMeta, method: str):
    return tag(n, kind="fused_impl", method=method,
               path="/".join(str(p) for p in meta.path))


def _ee(*args, **kw):
    """einsum with fp32 accumulation."""
    return jnp.einsum(*args, preferred_element_type=F32, **kw)


def _sumsq(tree):
    """Σ leaf² per example: every leaf has leading B."""
    leaves = jax.tree.leaves(tree)
    tot = 0.0
    for leaf in leaves:
        tot = tot + jnp.sum(
            jnp.square(leaf.astype(F32)),
            axis=tuple(range(1, leaf.ndim)))
    return tot


def _flatten_seq(x):
    """(B, *S, D) -> (B, T, D) with T = prod(S) (possibly 1)."""
    B, D = x.shape[0], x.shape[-1]
    return x.reshape(B, -1, D)


# ---------------------------------------------------------------------------
# Dense (batched)


def dense_pe_grad(meta: LayerMeta, cap, dy):
    x, g = _flatten_seq(cap["x"]), _flatten_seq(dy)
    if meta.w_transposed:
        w_grad = _ee("bto,bti->boi", g, x)
    else:
        w_grad = _ee("bti,bto->bio", x, g)
    out = {meta.param_key: w_grad}
    if meta.bias_key:
        out[meta.bias_key] = _ee("bto->bo", g)
    return out


def dense_norm_sq(meta: LayerMeta, cap, dy, method: str = "auto"):
    x, g = _flatten_seq(cap["x"]), _flatten_seq(dy)
    B, T, Di = x.shape
    Do = g.shape[-1]
    if method == "auto":
        method = costmodel.dense_norm_method(T, Di, Do, B)
    if method == "rank1" and T != 1:
        method = "gram"
    if method == "pallas":
        # VMEM-tiled Gram kernel (TPU; interpret elsewhere) — the (T,T)
        # tiles never touch HBM.
        from repro.kernels import ops as kops
        return _realized(kops.gram_norm(x, g, has_bias=bool(meta.bias_key)),
                         meta, "pallas")
    if method == "rank1":
        n = _ee("bti,bti->b", x, x) * _ee("bto,bto->b", g, g)
        if meta.bias_key:
            n = n + _ee("bto,bto->b", g, g)
        return _realized(n, meta, "rank1")
    if method == "stream":
        pe = dense_pe_grad(meta, cap, dy)
        return _realized(_sumsq(pe), meta, "stream")
    # gram, chunked over rows to bound the (B, T, T) intermediate
    chunk = costmodel.GRAM_CHUNK
    need_bias = bool(meta.bias_key)

    def chunk_norm(xc, gc):
        sx = _ee("bci,bti->bct", xc, x)
        sy = _ee("bco,bto->bct", gc, g)
        n = _ee("bct,bct->b", sx, sy)
        if need_bias:
            n = n + jnp.sum(sy, axis=(1, 2))
        return n

    if T <= chunk:
        return _realized(chunk_norm(x, g), meta, "gram")
    n_chunks, rem = divmod(T, chunk)
    xs = x[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, Di)
    gs = g[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, Do)

    def body(acc, xg):
        xc, gc = xg
        return acc + chunk_norm(xc, gc), None

    n, _ = jax.lax.scan(body, jnp.zeros((B,), F32),
                        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(gs, 1, 0)))
    if rem:
        n = n + chunk_norm(x[:, n_chunks * chunk:], g[:, n_chunks * chunk:])
    return _realized(n, meta, "gram")


def dense_norm_and_contrib(meta: LayerMeta, cap, dy, w, *,
                           method: str = "pallas"):
    """Fused phase: per-example squared norms *and* the weighted sum
    Σ_b w_b·g_b in one pass over (x, δy).

    ``method="pallas"`` routes through the VMEM-resident fused kernel (the
    contribution is accumulated from the same tiles the Gram norm already
    holds, so x/δy are read from HBM once).  ``method="stream"`` is the
    materializing realization: per-example grads are formed once and serve
    both reductions — this is what the planner's ``stash`` path exploits.
    Requires the weights to be known entering the pass (bk phase 2,
    stale-coefficient or per-layer-clipped pipelines).
    """
    if method == "pallas":
        from repro.kernels import ops as kops
        STATS.fused += 1
        x, g = _flatten_seq(cap["x"]), _flatten_seq(dy)
        n, cw, cb = kops.gram_norm_fused(x, g, w,
                                         has_bias=bool(meta.bias_key))
        out = {meta.param_key: cw.T if meta.w_transposed else cw}
        if meta.bias_key:
            out[meta.bias_key] = cb
        return _fused_marker(n, meta, "pallas"), out
    pe = dense_pe_grad(meta, cap, dy)
    n = _sumsq(pe)
    contrib = jax.tree.map(
        lambda leaf: _ee("b...,b->...", leaf, w.astype(F32)), pe)
    return _fused_marker(n, meta, "stream"), contrib


def dense_contrib(meta: LayerMeta, cap, dy, w):
    x, g = _flatten_seq(cap["x"]), _flatten_seq(dy)
    if meta.w_transposed:
        w_grad = _ee("b,bto,bti->oi", w, g, x)
    else:
        w_grad = _ee("b,bti,bto->io", w, x, g)
    out = {meta.param_key: w_grad}
    if meta.bias_key:
        out[meta.bias_key] = _ee("b,bto->o", w, g)
    return out


# ---------------------------------------------------------------------------
# Dense (segmented: MoE expert slots with explicit example ids)


def _seg_flatten(meta, cap, dy):
    """Returns x (G,S,Di), g (G,S,Do), seg (G,S), n_examples B."""
    x, g, seg = cap["x"], dy, cap["seg"]
    Di, Do, S = x.shape[-1], g.shape[-1], x.shape[-2]
    x = x.reshape(-1, S, Di)
    g = g.reshape(-1, S, Do)
    seg = seg.reshape(-1, S)
    return x, g, seg, meta.static["n_examples"]


def seg_dense_pe_grad(meta: LayerMeta, cap, dy):
    x, g, seg, B = _seg_flatten(meta, cap, dy)
    oh = jax.nn.one_hot(seg, B, dtype=x.dtype)                 # (G,S,B)
    w_grad = _ee("gsb,gsi,gso->bgio", oh, x, g)
    w_grad = w_grad.reshape((B,) + cap["x"].shape[:-2] + w_grad.shape[-2:])
    out = {meta.param_key: w_grad}
    if meta.bias_key:
        bg = _ee("gsb,gso->bgo", oh, g)
        out[meta.bias_key] = bg.reshape((B,) + cap["x"].shape[:-2] + bg.shape[-1:])
    return out


def seg_dense_norm_sq(meta: LayerMeta, cap, dy, method: str = "auto"):
    x, g, seg, B = _seg_flatten(meta, cap, dy)
    G, S, Di = x.shape
    Do = g.shape[-1]
    if method == "auto":
        method = costmodel.seg_norm_method(S, Di, Do, B, G)
    # Both methods scan over the group (expert) axis so peak extra memory
    # is one group's worth: (B,Di,Do) for stream, (S,S) for gram.
    if method == "stream":
        def body(acc, xgs):
            xg, gg, sg = xgs
            oh = jax.nn.one_hot(sg, B, dtype=xg.dtype)          # (S,B)
            pe = _ee("sb,si,so->bio", oh, xg, gg)
            acc = acc + jnp.sum(jnp.square(pe), axis=(1, 2))
            if meta.bias_key:
                peb = _ee("sb,so->bo", oh, gg)
                acc = acc + jnp.sum(jnp.square(peb), axis=1)
            return acc, None
    else:  # gram over slots with same-example masking
        def body(acc, xgs):
            xg, gg, sg = xgs
            p = _ee("si,ti->st", xg, xg) * _ee("so,to->st", gg, gg)
            if meta.bias_key:
                p = p + _ee("so,to->st", gg, gg)
            oh = jax.nn.one_hot(sg, B, dtype=F32)               # (S,B)
            acc = acc + _ee("sb,st,tb->b", oh, p, oh)
            return acc, None

    n, _ = jax.lax.scan(body, jnp.zeros((B,), F32), (x, g, seg))
    return _realized(n, meta, method)


def seg_dense_contrib(meta: LayerMeta, cap, dy, w):
    x, g, seg, B = _seg_flatten(meta, cap, dy)
    ws = w[seg]                                                 # (G,S)
    w_grad = _ee("gs,gsi,gso->gio", ws, x, g)
    w_grad = w_grad.reshape(cap["x"].shape[:-2] + w_grad.shape[-2:])
    out = {meta.param_key: w_grad}
    if meta.bias_key:
        bg = _ee("gs,gso->go", ws, g)
        out[meta.bias_key] = bg.reshape(cap["x"].shape[:-2] + bg.shape[-1:])
    return out


# ---------------------------------------------------------------------------
# Embedding (gather)


def embed_pe_grad(meta: LayerMeta, cap, dy, vocab: int):
    ids, g = cap["ids"], dy
    B = ids.shape[0]
    ids2 = ids.reshape(B, -1)
    g2 = g.reshape(B, ids2.shape[1], -1).astype(F32)
    out = jnp.zeros((B, vocab, g2.shape[-1]), F32)
    bidx = jnp.arange(B)[:, None]
    out = out.at[bidx, ids2].add(g2)
    return {meta.param_key: out}


def embed_norm_sq(meta: LayerMeta, cap, dy, method: str = "segsum",
                  vocab: int | None = None):
    """Embedding-gather ghost norm: ‖g_b‖² = Σ_v ‖Σ_{t: id_t=v} δy_t‖².

    ``segsum`` (default): sort tokens, segment-sum cotangent rows, square —
    O(T·logT + T·D).  ``gram``: same-token-masked T×T Gram — O(T²·D); at
    T=4096 the gram costs ~2.4× the *whole model's* training FLOPs, which
    the dry-run FLOP parser exposed (EXPERIMENTS.md §Perf iteration 1).
    ``pe``: materialize the (B, V, D) per-example grad and reduce — the
    sort-free winner for small tables (see costmodel.embed_norm_method).
    """
    ids, g = cap["ids"], dy
    B = ids.shape[0]
    ids2 = ids.reshape(B, -1)
    T = ids2.shape[1]
    g2 = g.reshape(B, T, -1)
    if method == "auto":
        method = costmodel.embed_norm_method(T, g2.shape[-1], B, vocab)
    if method == "pe":
        return _realized(_sumsq(embed_pe_grad(meta, cap, dy, vocab)),
                         meta, "pe")
    if method == "gram":
        sy = _ee("btd,bsd->bts", g2, g2)
        m = (ids2[:, :, None] == ids2[:, None, :]).astype(F32)
        return _realized(_ee("bts,bts->b", m, sy), meta, "gram")
    # segsum
    order = jnp.argsort(ids2, axis=1)
    ids_s = jnp.take_along_axis(ids2, order, axis=1)
    g_s = jnp.take_along_axis(g2, order[..., None], axis=1).astype(F32)
    newseg = jnp.cumsum(
        jnp.concatenate([jnp.zeros((B, 1), jnp.int32),
                         (ids_s[:, 1:] != ids_s[:, :-1]).astype(jnp.int32)],
                        axis=1), axis=1)
    summed = jax.vmap(
        lambda gg, ss: jax.ops.segment_sum(gg, ss, num_segments=T))(
        g_s, newseg)
    return _realized(jnp.sum(jnp.square(summed), axis=(1, 2)),
                     meta, "segsum")


def embed_contrib(meta: LayerMeta, cap, dy, w, vocab: int):
    ids, g = cap["ids"], dy
    B = ids.shape[0]
    ids2 = ids.reshape(B, -1)
    g2 = g.reshape(B, ids2.shape[1], -1).astype(F32)
    g2 = g2 * w[:, None, None]
    out = jnp.zeros((vocab, g2.shape[-1]), F32)
    out = out.at[ids2.reshape(-1)].add(g2.reshape(-1, g2.shape[-1]))
    return {meta.param_key: out}


# ---------------------------------------------------------------------------
# Scale / bias (elementwise affine)


def _scale_reduce_axes(x, gshape):
    """Axes of x (beyond batch) over which the g-broadcast reduces."""
    nd, ng = x.ndim, len(gshape)
    axes = []
    for ax in range(1, nd):
        gax = ax - (nd - ng)
        if gax < 0 or gshape[gax] == 1:
            axes.append(ax)
    return tuple(axes)


def scale_pe_grad(meta: LayerMeta, cap, dy, gshape):
    x, g = cap["x"], dy
    axes = _scale_reduce_axes(x, gshape)
    pg = jnp.sum((x * g).astype(F32), axis=axes)
    out = {meta.param_key: pg.reshape((x.shape[0],) + tuple(gshape))}
    if meta.bias_key:
        pb = jnp.sum(g.astype(F32), axis=axes)
        out[meta.bias_key] = pb.reshape((x.shape[0],) + tuple(gshape))
    return out


def scale_norm_sq(meta: LayerMeta, cap, dy, gshape):
    return _realized(_sumsq(scale_pe_grad(meta, cap, dy, gshape)),
                     meta, "pe")


def scale_contrib(meta: LayerMeta, cap, dy, w, gshape):
    pe = scale_pe_grad(meta, cap, dy, gshape)
    wb = w.reshape((-1,) + (1,) * len(gshape))
    return {k: jnp.sum(v * wb, axis=0) for k, v in pe.items()}


# ---------------------------------------------------------------------------
# Convolution (the paper's contribution — Algorithms 1 & 2)


def conv_pe_grad(meta: LayerMeta, cap, dy, impl: str = "fgc"):
    from repro.models import convops
    st = meta.static
    w_grad = convops.pe_conv_grad(
        cap["x"], dy, kernel_spatial=st["kernel_shape"][2:],
        stride=st["stride"], dilation=st["dilation"], padding=st["padding"],
        groups=st["groups"], impl=impl)
    out = {meta.param_key: w_grad}
    if meta.bias_key:
        g = dy
        out[meta.bias_key] = jnp.sum(
            g.astype(F32), axis=tuple(range(2, g.ndim)))
    return out


def conv_norm_sq_ghost(meta: LayerMeta, cap, dy, *, use_pallas: bool = False):
    """Conv ghost norm without materializing per-example weight grads:
    im2col the input to x̃ (B, T, C·K/g per group) and apply the dense Gram
    identity  ‖g_b‖² = Σ_{t,t'} (x̃_t·x̃_{t'}) (δy_t·δy_{t'})  per group —
    the per-layer "ghost clipping" of Bu et al. (2022) generalized to
    stride/dilation/padding/groups.  Cost 2·B·T²·(C·K/g + D/g)·g vs the
    materializing path's 4·B·T·(C·K/g)·(D/g)·g: wins exactly where the
    cost model says (small output spatial T, wide channels)."""
    from repro.models.convops import unfold_patches
    st = meta.static
    x = cap["x"]
    g = max(st.get("groups", 1), 1)
    patches = unfold_patches(x, st["kernel_shape"][2:], stride=st["stride"],
                             dilation=st["dilation"], padding=st["padding"])
    B, CK, T = patches.shape
    D = dy.shape[1]
    gy = dy.reshape(B, D, T)
    method = "pallas" if use_pallas else "gram"
    if g == 1:
        meta_d = LayerMeta("dense", meta.path, bias_key=meta.bias_key)
        return dense_norm_sq(meta_d, {"x": patches.transpose(0, 2, 1)},
                             gy.transpose(0, 2, 1), method=method)
    Fg, Dg = CK // g, D // g
    xt = patches.reshape(B, g, Fg, T).transpose(0, 1, 3, 2) \
        .reshape(B * g, T, Fg)
    gt = gy.reshape(B, g, Dg, T).transpose(0, 1, 3, 2).reshape(B * g, T, Dg)
    meta_d = LayerMeta("dense", meta.path)
    n = dense_norm_sq(meta_d, {"x": xt}, gt, method=method)
    n = jnp.sum(n.reshape(B, g), axis=1)
    if meta.bias_key:
        sb = jnp.sum(gy.astype(F32), axis=2)
        n = n + jnp.sum(jnp.square(sb), axis=1)
    return n


def conv_norm_sq(meta: LayerMeta, cap, dy, impl: str = "fgc",
                 method: str = "pe"):
    if method == "auto":
        st = meta.static
        T = int(np.prod(dy.shape[2:]))
        K = int(np.prod(st["kernel_shape"][2:]))
        method = costmodel.conv_norm_method(
            T, cap["x"].shape[1], dy.shape[1], K, dy.shape[0],
            max(st.get("groups", 1), 1))
    if method in ("ghost", "pallas"):
        return _realized(conv_norm_sq_ghost(
            meta, cap, dy, use_pallas=(method == "pallas")), meta, method)
    return _realized(_sumsq(conv_pe_grad(meta, cap, dy, impl=impl)),
                     meta, "pe")


def conv_norm_and_contrib(meta: LayerMeta, cap, dy, w, *,
                          use_pallas: bool = True):
    """Fused conv ghost-norm + weighted weight gradient: im2col the input
    and run the dense fused pass per group — the contribution
    Σ_b w_b x̃_bᵀ δy_b *is* the weighted conv weight gradient in patch
    space (channel-major / filter-position-minor, matching the
    (D, C/g, *K) weight layout), so the reshape back is free.  Requires
    the weights to be known entering the pass (stale-coefficient
    pipelines)."""
    from repro.models.convops import unfold_patches
    st = meta.static
    x = cap["x"]
    g = max(st.get("groups", 1), 1)
    kshape = st["kernel_shape"]
    patches = unfold_patches(x, kshape[2:], stride=st["stride"],
                             dilation=st["dilation"], padding=st["padding"])
    B, CK, T = patches.shape
    D = dy.shape[1]
    gy = dy.reshape(B, D, T)
    method = "pallas" if use_pallas else "stream"
    if g == 1:
        meta_d = LayerMeta("dense", meta.path, param_key=meta.param_key,
                           bias_key=meta.bias_key)
        n, out = dense_norm_and_contrib(
            meta_d, {"x": patches.transpose(0, 2, 1)},
            gy.transpose(0, 2, 1), w, method=method)
        out[meta.param_key] = out[meta.param_key].T.reshape(kshape)
        return n, out
    Fg, Dg = CK // g, D // g
    xg = patches.reshape(B, g, Fg, T)
    gg = gy.reshape(B, g, Dg, T)
    meta_d = LayerMeta("dense", meta.path, param_key=meta.param_key)
    n = jnp.zeros((B,), F32)
    w_parts = []
    for gi in range(g):
        n_i, out = dense_norm_and_contrib(
            meta_d, {"x": xg[:, gi].transpose(0, 2, 1)},
            gg[:, gi].transpose(0, 2, 1), w, method=method)
        n = n + n_i
        w_parts.append(out[meta.param_key].T.reshape((Dg,) + tuple(kshape[1:])))
    res = {meta.param_key: jnp.concatenate(w_parts, axis=0)}
    if meta.bias_key:
        sb = jnp.sum(gy.astype(F32), axis=2)                    # (B, D)
        n = n + jnp.sum(jnp.square(sb), axis=1)
        res[meta.bias_key] = _ee("b,bo->o", w.astype(F32), sb)
    return n, res


def conv_contrib(meta: LayerMeta, cap, dy, w):
    from repro.models.convops import conv_forward
    st = meta.static
    x = cap["x"] * w.reshape((-1,) + (1,) * (cap["x"].ndim - 1)).astype(cap["x"].dtype)
    kshape = st["kernel_shape"]

    def f(wk):
        return conv_forward(x, wk, stride=st["stride"], dilation=st["dilation"],
                            padding=st["padding"], groups=st["groups"])

    _, vjp = jax.vjp(f, jnp.zeros(kshape, cap["x"].dtype))
    (w_grad,) = vjp(dy.astype(cap["x"].dtype))
    out = {meta.param_key: w_grad.astype(F32)}
    if meta.bias_key:
        g = dy.astype(F32) * w.reshape((-1,) + (1,) * (dy.ndim - 1))
        out[meta.bias_key] = jnp.sum(g, axis=(0,) + tuple(range(2, g.ndim)))
    return out


# ---------------------------------------------------------------------------
# Attention blocks (GQA / MLA, tapped as one "attn" layer)
#
# The block tap captures only the block *input* x_b and receives the block
# *output* cotangent δy_b from the model backward.  A layer-local recompute
# under an inner Tapper then recovers every projection's (x, δy) pair:
# differentiating  Σ_b ⟨y_b, δy_b⟩  w.r.t. the inner taps yields exactly the
# chain-rule cotangents of the true loss at each projection output (δy is
# constant w.r.t. the taps), after which each projection applies its own
# dense/scale algebra — the ghost norm never materializes per-example
# attention gradients, matching the paper's conv derivation ported to the
# attention contraction.  Like local_vjp this is layer-local recompute, not
# a whole-model pass: no STATS ticks, the census stays 1 fwd + 1 bwd.


def _attn_parts(meta: LayerMeta, cap, dy, params_sub):
    """Recompute the block, returning (inner_metas, caps, dtaps) with each
    inner tap's captures and output cotangents.  Inner tap names are rooted
    at the fixed "blk" prefix (see gqa_apply/mla_apply), so the relative
    param path of an inner layer is ``meta.path[1:]``."""
    x = cap["x"]
    inner_metas: dict[str, LayerMeta] = {}

    def probe_fn(p, xin):
        tp = Tapper(None, "probe", metas=inner_metas)
        y = meta.fn(tp, p, xin)
        return y, tp.captures

    _, cap_sh = jax.eval_shape(probe_fn, params_sub, x)
    taps = {n: jnp.zeros(c[TAP_KEY].shape, c[TAP_KEY].dtype)
            for n, c in cap_sh.items() if TAP_KEY in c}
    dyf = dy.astype(F32)

    def from_taps(t):
        tp = Tapper(t, "capture", metas={})
        y = meta.fn(tp, params_sub, x)
        return jnp.sum(y.astype(F32) * dyf), tp.captures

    (_, caps), dtaps = jax.value_and_grad(from_taps, has_aux=True)(taps)
    return inner_metas, caps, dtaps


def _attn_each(meta: LayerMeta, params_sub, inner_metas):
    """Yield (name, flat inner meta re-rooted under meta.path, rel path,
    param subtree) per inner tap, in deterministic order."""
    for iname in sorted(inner_metas):
        im = inner_metas[iname]
        rel = im.path[1:]
        imf = dataclasses.replace(im, path=meta.path + rel, scanned=0,
                                  shared=False)
        yield iname, imf, rel, get_subtree(params_sub, rel)


def attn_pe_grad(meta: LayerMeta, cap, dy, params_sub):
    inner_metas, caps, dtaps = _attn_parts(meta, cap, dy, params_sub)
    out: dict = {}
    for iname, imf, rel, psub_i in _attn_each(meta, params_sub, inner_metas):
        part = _apply_flat("pe_grad", imf, caps[iname], dtaps[iname],
                           params_sub=psub_i, weights=None,
                           norm_method="auto", conv_impl="fgc")
        for k2, v2 in part.items():
            out = set_subtree(out, rel + (k2,), v2)
    return out


def attn_norm_sq(meta: LayerMeta, cap, dy, params_sub, method: str = "auto"):
    if method == "auto":
        method = "ghost"
    if method == "pe":
        return _realized(_sumsq(attn_pe_grad(meta, cap, dy, params_sub)),
                         meta, "pe")
    inner_metas, caps, dtaps = _attn_parts(meta, cap, dy, params_sub)
    n = jnp.zeros((cap["x"].shape[0],), F32)
    for iname, imf, rel, psub_i in _attn_each(meta, params_sub, inner_metas):
        n = n + _apply_flat("norm_sq", imf, caps[iname], dtaps[iname],
                            params_sub=psub_i, weights=None,
                            norm_method="auto", conv_impl="fgc")
    return _realized(n, meta, "ghost")


def attn_contrib(meta: LayerMeta, cap, dy, w, params_sub):
    inner_metas, caps, dtaps = _attn_parts(meta, cap, dy, params_sub)
    out: dict = {}
    for iname, imf, rel, psub_i in _attn_each(meta, params_sub, inner_metas):
        part = _apply_flat("contrib", imf, caps[iname], dtaps[iname],
                           params_sub=psub_i, weights=w,
                           norm_method="auto", conv_impl="fgc")
        for k2, v2 in part.items():
            out = set_subtree(out, rel + (k2,), v2)
    return out


# ---------------------------------------------------------------------------
# Generic local-VJP kind (SSM scans, routers, anything else)


def _local_vjp_pe(meta: LayerMeta, cap, dy, params_sub):
    def one(inputs_b, dy_b):
        def f(p):
            return meta.fn(p, *jax.tree.map(lambda a: a[None], inputs_b))
        y, vjp = jax.vjp(f, params_sub)
        (g,) = vjp(dy_b[None].astype(y.dtype))
        return g
    return jax.vmap(one)(cap["inputs"], dy)


def local_vjp_pe_grad(meta: LayerMeta, cap, dy, params_sub):
    return _local_vjp_pe(meta, cap, dy, params_sub)


def local_vjp_norm_sq(meta: LayerMeta, cap, dy, params_sub):
    return _realized(_sumsq(_local_vjp_pe(meta, cap, dy, params_sub)),
                     meta, "vjp")


def local_vjp_contrib(meta: LayerMeta, cap, dy, w, params_sub):
    pe = _local_vjp_pe(meta, cap, dy, params_sub)
    return jax.tree.map(
        lambda leaf: jnp.einsum(
            "b...,b->...", leaf.astype(F32), w.astype(F32)), pe)


# ---------------------------------------------------------------------------
# Stacked-layer handling: fold meta.scanned leading axes


def _split_stack(meta: LayerMeta, cap, dy):
    """Flatten the stacked-layer axes into one leading G axis."""
    k = meta.scanned

    def flat(a):
        return a.reshape((-1,) + a.shape[k:])

    stack_shape = dy.shape[:k]
    return jax.tree.map(flat, cap), flat(dy), stack_shape


def _fold_into_seq(meta: LayerMeta, cap, dy):
    """For shared params: fold stacked axes into the sequence axis so the
    per-example gradient is summed over applications *before* norms."""
    k = meta.scanned
    if k == 0:
        return cap, dy

    def fold(a):
        # (S1..Sk, B, *rest, D) -> (B, S*prod(rest_mid), D) handled by
        # downstream _flatten_seq; here just move stack axes after batch.
        a = jnp.moveaxis(a.reshape((-1,) + a.shape[k:]), 0, 1)
        return a
    return jax.tree.map(fold, cap), jax.tree.map(fold, dy)


def apply_kind(op: str, meta: LayerMeta, cap, dy, *, params_sub=None,
               weights=None, norm_method: str = "auto", conv_impl: str = "fgc",
               embed_method: str = "segsum", conv_norm: str = "pe",
               attn_norm: str = "auto"):
    """Dispatch `op` in {"pe_grad","norm_sq","contrib"} over any kind,
    handling stacked (scanned) axes and shared parameters."""
    kind = meta.kind

    if meta.shared and meta.scanned and kind in ("dense", "scale") \
            and not meta.segmented:
        # Fold applications into the sequence axis: the per-example gradient
        # of a shared parameter is the sum over applications, and the fold
        # makes every op (incl. the Gram norm with its cross terms) exact.
        cap, dy = _fold_into_seq(meta, cap, dy)
        return _apply_flat(op, _unscanned(meta), cap, dy,
                           params_sub=params_sub, weights=weights,
                           norm_method=norm_method, conv_impl=conv_impl,
                           embed_method=embed_method, conv_norm=conv_norm,
                           attn_norm=attn_norm)

    if meta.shared and meta.scanned and op == "norm_sq":
        # Generic shared fallback: materialize the summed per-example grad
        # (exact cross terms), then take norms.
        pe = apply_kind("pe_grad", meta, cap, dy, params_sub=params_sub,
                        conv_impl=conv_impl)
        return _realized(_sumsq(pe), meta, "pe")

    if meta.scanned and meta.segmented:
        # Segmented (MoE) kinds natively reduce over their leading group
        # axis with a memory-bounded internal scan — just flatten stacks.
        cap_f, dy_f, stack_shape = _split_stack(meta, cap, dy)
        res = _apply_flat(op, _unscanned(meta), cap_f, dy_f,
                          params_sub=params_sub, weights=weights,
                          norm_method=norm_method, conv_impl=conv_impl,
                          embed_method=embed_method, conv_norm=conv_norm,
                          attn_norm=attn_norm)
        if op == "norm_sq":
            return res
        if op == "contrib":
            return jax.tree.map(
                lambda a: a.reshape(stack_shape + a.shape[1:]), res)
        return jax.tree.map(  # pe_grad: (B, G, ...) -> (B, *stack, ...)
            lambda a: a.reshape((a.shape[0],) + stack_shape + a.shape[2:]),
            res)

    if meta.scanned:
        cap_f, dy_f, stack_shape = _split_stack(meta, cap, dy)
        meta_f = _unscanned(meta)
        psub = params_sub
        shared_p = psub if (psub is not None and meta.shared) else None
        if psub is not None and not meta.shared:
            psub = jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[meta.scanned:]), psub)
        else:
            psub = None

        def one(xs):
            c, d, p = xs
            return _apply_flat(op, meta_f, c, d,
                               params_sub=shared_p if shared_p is not None
                               else p,
                               weights=weights, norm_method=norm_method,
                               conv_impl=conv_impl,
                               embed_method=embed_method,
                               conv_norm=conv_norm, attn_norm=attn_norm)

        # Sequential over the stacked axis: bounds peak memory to one
        # layer's worth (vmap would batch every layer's intermediates).
        res = jax.lax.map(one, (cap_f, dy_f, psub))

        if op == "norm_sq":
            return jnp.sum(res, axis=0)
        if op == "contrib":
            if meta.shared:
                return jax.tree.map(lambda a: jnp.sum(a, axis=0), res)
            return jax.tree.map(
                lambda a: a.reshape(stack_shape + a.shape[1:]), res)
        # pe_grad: (G, B, *p) -> (B, *stack, *p)
        if meta.shared:
            return jax.tree.map(lambda a: jnp.sum(a, axis=0), res)
        return jax.tree.map(
            lambda a: jnp.moveaxis(
                a.reshape(stack_shape + a.shape[1:]), len(stack_shape), 0),
            res)

    return _apply_flat(op, meta, cap, dy, params_sub=params_sub,
                       weights=weights, norm_method=norm_method,
                       conv_impl=conv_impl, embed_method=embed_method,
                       conv_norm=conv_norm, attn_norm=attn_norm)


def apply_norm_contrib(meta: LayerMeta, cap, dy, *, weights,
                       params_sub=None, fused: bool = True,
                       conv_impl: str = "fgc", norm_method: str = "auto",
                       embed_method: str = "segsum",
                       conv_norm: str = "auto", attn_norm: str = "auto"):
    """Per-example squared norms *and* the weighted sum Σ_b w_b·g_b from
    one pass over the captures.  Valid whenever the weights are known
    entering the pass (stale-coefficient clipping).

    Dense (non-segmented) and conv layers route to the fused
    ``gram_norm_fused`` realizations when ``fused``; every other kind —
    and the non-fused request — falls back to its norm_sq + contrib pair
    (still a single capture pass of the model: no extra forward or
    backward, just two reductions over the same tensors)."""
    if fused and meta.kind == "dense" and not meta.segmented:
        if meta.shared and meta.scanned:
            cap2, dy2 = _fold_into_seq(meta, cap, dy)
            return dense_norm_and_contrib(_unscanned(meta), cap2, dy2,
                                          weights, method="pallas")
        if not meta.scanned:
            return dense_norm_and_contrib(meta, cap, dy, weights,
                                          method="pallas")
        cap_f, dy_f, stack_shape = _split_stack(meta, cap, dy)
        meta_f = _unscanned(meta)

        def one(xs):
            c, d = xs
            return dense_norm_and_contrib(meta_f, c, d, weights,
                                          method="pallas")

        n, contrib = jax.lax.map(one, (cap_f, dy_f))
        n = jnp.sum(n, axis=0)
        contrib = jax.tree.map(
            lambda a: a.reshape(stack_shape + a.shape[1:]), contrib)
        return n, contrib
    if fused and meta.kind == "conv" and not meta.scanned:
        return conv_norm_and_contrib(meta, cap, dy, weights, use_pallas=True)
    n = apply_kind("norm_sq", meta, cap, dy, params_sub=params_sub,
                   norm_method=norm_method, conv_impl=conv_impl,
                   embed_method=embed_method, conv_norm=conv_norm,
                   attn_norm=attn_norm)
    c = apply_kind("contrib", meta, cap, dy, params_sub=params_sub,
                   weights=weights, conv_impl=conv_impl)
    return n, c


def _unscanned(meta: LayerMeta) -> LayerMeta:
    import dataclasses as dc
    return dc.replace(meta, scanned=0, shared=False)


def _apply_flat(op, meta, cap, dy, *, params_sub, weights, norm_method,
                conv_impl, embed_method="segsum", conv_norm="pe",
                attn_norm="auto"):
    kind = meta.kind
    if kind == "dense" and not meta.segmented:
        if op == "pe_grad":
            return dense_pe_grad(meta, cap, dy)
        if op == "norm_sq":
            return dense_norm_sq(meta, cap, dy, method=norm_method)
        return dense_contrib(meta, cap, dy, weights)
    if kind == "dense" and meta.segmented:
        if op == "pe_grad":
            return seg_dense_pe_grad(meta, cap, dy)
        if op == "norm_sq":
            return seg_dense_norm_sq(meta, cap, dy, method=norm_method)
        return seg_dense_contrib(meta, cap, dy, weights)
    if kind == "embed":
        vocab = (params_sub[meta.param_key].shape[-2]
                 if params_sub is not None else meta.static.get("vocab"))
        if op == "pe_grad":
            return embed_pe_grad(meta, cap, dy, vocab)
        if op == "norm_sq":
            return embed_norm_sq(meta, cap, dy, method=embed_method,
                                 vocab=vocab)
        return embed_contrib(meta, cap, dy, weights, vocab)
    if kind == "scale":
        gshape = tuple(params_sub[meta.param_key].shape)
        if op == "pe_grad":
            return scale_pe_grad(meta, cap, dy, gshape)
        if op == "norm_sq":
            return scale_norm_sq(meta, cap, dy, gshape)
        return scale_contrib(meta, cap, dy, weights, gshape)
    if kind == "conv":
        if op == "pe_grad":
            return conv_pe_grad(meta, cap, dy, impl=conv_impl)
        if op == "norm_sq":
            return conv_norm_sq(meta, cap, dy, impl=conv_impl,
                                method=conv_norm)
        return conv_contrib(meta, cap, dy, weights)
    if kind == "local_vjp":
        if op == "pe_grad":
            return local_vjp_pe_grad(meta, cap, dy, params_sub)
        if op == "norm_sq":
            return local_vjp_norm_sq(meta, cap, dy, params_sub)
        return local_vjp_contrib(meta, cap, dy, weights, params_sub)
    if kind == "attn":
        if op == "pe_grad":
            return attn_pe_grad(meta, cap, dy, params_sub)
        if op == "norm_sq":
            return attn_norm_sq(meta, cap, dy, params_sub, method=attn_norm)
        return attn_contrib(meta, cap, dy, weights, params_sub)
    raise ValueError(f"unknown kind {kind}")


# ---------------------------------------------------------------------------
# Tied-parameter cross term: <g_embed_b, g_head_b> for weight-tied LM heads


def tied_embed_head_cross(cap_e, dy_e, cap_d, dy_d):
    """2·⟨g_in, g_out⟩ per example for a parameter used both as an embedding
    table (gather) and, transposed, as the LM head (dense w_transposed).

      g_in[v,d]  = Σ_t 1[id_t=v] δe[t,d]
      g_out[v,d] = Σ_s δl[s,v] h[s,d]
      ⟨g_in,g_out⟩ = Σ_{t,s} δl[s, id_t] · (δe[t]·h[s])
    """
    ids = cap_e["ids"]
    B = ids.shape[0]
    ids2 = ids.reshape(B, -1)                      # (B, T)
    de = dy_e.reshape(B, ids2.shape[1], -1)        # (B, T, D)
    h = _flatten_seq(cap_d["x"])                   # (B, S, D)
    dl = dy_d.reshape(B, h.shape[1], -1)           # (B, S, V)
    a = _ee("btd,bsd->bts", de, h)                 # (B, T, S)
    idx = jnp.broadcast_to(ids2[:, None, :], (B, h.shape[1], ids2.shape[1]))
    dl_at = jnp.take_along_axis(dl, idx, axis=2)   # (B, S, T)
    inner = _ee("bts,bst->b", a, dl_at)
    return 2.0 * inner
