"""Llama-3.2-1B [hf:meta-llama; unverified] — dense, GQA kv=8, tied."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=32, n_kv=8, d_ff=8192, vocab=128256, head_dim=64,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=True, rope_theta=5e5,
    dtype="bfloat16", remat=False, dp_strategy="bk", prefill_last_only=True)
