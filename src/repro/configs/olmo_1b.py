"""OLMo-1B [arXiv:2402.00838; hf] — dense, non-parametric LayerNorm, tied."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048, n_heads=16,
    n_kv=16, d_ff=8192, vocab=50304, head_dim=128, norm="layernorm_np",
    mlp="swiglu", tie_embeddings=True, rope_theta=1e4, dtype="bfloat16",
    remat=False, dp_strategy="bk", prefill_last_only=True)
