"""Granite-3.0-1B-A400M [hf:ibm-granite; hf] — MoE 32 experts top-8.

d_ff=512 is the per-expert hidden size.  Vocab 49155 padded to 49280.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv=8, d_ff=512, vocab=49155, head_dim=64, norm="rmsnorm",
    mlp="swiglu", n_experts=32, topk=8, capacity_factor=2.0,
    rope_theta=1e4, dtype="bfloat16", moe_impl="gather", dp_strategy="ghost",
    prefill_last_only=True)
