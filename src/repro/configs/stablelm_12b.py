"""StableLM-2-12B [hf:stabilityai; hf] — dense GQA kv=8, FSDP at 12B."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv=8, d_ff=13824, vocab=100352, head_dim=160,
    norm="layernorm", mlp="swiglu", rope_theta=1e4, dtype="bfloat16",
    remat=True, fsdp=True, dp_strategy="bk", prefill_last_only=True)
