"""Assigned architecture configs (+ the paper's own CNNs).

Every entry is selectable via ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec


def _get(name: str) -> ModelConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


ARCH_IDS = [
    "olmo-1b", "stablelm-12b", "glm4-9b", "llama3.2-1b", "xlstm-125m",
    "seamless-m4t-large-v2", "zamba2-2.7b", "chameleon-34b",
    "granite-moe-1b-a400m", "deepseek-v3-671b",
]
PAPER_IDS = ["alexnet", "vgg16"]

_MOD = {
    "olmo-1b": "olmo_1b", "stablelm-12b": "stablelm_12b",
    "glm4-9b": "glm4_9b", "llama3.2-1b": "llama32_1b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-2.7b": "zamba2_2p7b", "chameleon-34b": "chameleon_34b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "alexnet": "alexnet", "vgg16": "vgg16",
}


def get_config(arch: str) -> ModelConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{_MOD[arch]}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS + PAPER_IDS}


__all__ = ["ARCH_IDS", "PAPER_IDS", "SHAPES", "ModelConfig", "ShapeSpec",
           "get_config", "all_configs"]
