"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 + weight-shared attn block.

54 Mamba2 layers in 9 super-blocks of 6, one *shared* full attention+MLP
block applied after each super-block (Zamba's parameter-sharing trick; the
per-depth LoRA of Zamba2 is omitted, see DESIGN.md).  Sliding-window
attention (window=4096) keeps it sub-quadratic for long_500k decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv=32, d_ff=10240, vocab=32000, head_dim=80,
    norm="rmsnorm", mlp="swiglu", ssm_state=64, ssm_expand=2, ssm_conv=4,
    attn_every=6, window=4096, rope_theta=1e4, dtype="bfloat16", remat=True,
    subquadratic=True, dp_strategy="bk", prefill_last_only=True)
