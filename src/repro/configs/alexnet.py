"""AlexNet (paper Table 1), torchvision layout, 3x256x256 inputs."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="alexnet", family="cnn", n_layers=5, d_model=0, n_heads=0, n_kv=0,
    d_ff=0, vocab=0, cnn_arch="alexnet", img_size=256, n_classes=1000)
