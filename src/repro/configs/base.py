"""Model / shape / run configuration schema."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    # attention
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 5e5
    qk_norm: bool = False
    attn_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm | layernorm_np
    mlp: str = "swiglu"            # swiglu | gelu
    attn_impl: str = "auto"        # auto | xla | chunked | flash
    dp_attn: bool = False          # block-level "attn" DP tap (kinds.py)
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    topk: int = 0
    capacity_factor: float = 2.0
    moe_impl: str = "einsum"       # einsum | gather
    # mla (deepseek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    mla_absorbed_decode: bool = False
    # ssm / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0            # zamba: shared attn block every k ssm layers
    slstm_every: int = 0           # xlstm: one sLSTM per k-block (else mLSTM)
    window: int = 0                # sliding-window attention (long-context)
    # enc-dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    frontend: str = "none"         # "frames": inputs are embeddings (stub)
    # cnn (paper models)
    cnn_arch: str = ""             # alexnet | vgg16 | toy
    cnn_channels: tuple = ()
    cnn_kernel: int = 3
    img_size: int = 224
    n_classes: int = 1000
    # serving
    prefill_last_only: bool = False   # head matmul on last position only
    # numerics / distribution hints
    dtype: str = "float32"
    remat: bool = False
    fsdp: bool = False
    vocab_pad_to: int = 128
    dp_strategy: str = "ghost"
    moe_lb_coef: float = 0.01
    # long-context applicability: full-attention archs skip long_500k
    subquadratic: bool = False

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab, self.vocab_pad_to)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 * max(1, self.attn_every or 0) or 2),
            d_model=64, n_heads=4, n_kv=min(self.n_kv, 2) or 2,
            d_ff=96 if self.n_experts else 128,
            vocab=512, head_dim=16, dtype="float32", remat=False, fsdp=False)
        if self.attn_every:
            kw["attn_every"] = 2
            kw["n_layers"] = 4
        if self.slstm_every:
            kw["slstm_every"] = 2
            kw["n_layers"] = 4
        if self.n_experts:
            kw["n_experts"] = 4
            kw["topk"] = 2
        if self.mla:
            kw.update(q_lora_rank=32, kv_lora_rank=32, qk_rope_dim=8,
                      qk_nope_dim=16, v_head_dim=16)
        if self.n_enc_layers:
            kw.update(n_enc_layers=2, n_dec_layers=2, n_layers=4)
        if self.ssm_state:
            kw["ssm_state"] = 16
        if self.family == "cnn":
            kw = dict(cnn_arch="toy", cnn_channels=(8, 16), cnn_kernel=3,
                      img_size=32, n_classes=10)
        return self.replace(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
