"""SeamlessM4T-large-v2 backbone [arXiv:2308.11596; hf] — enc-dec.

24L read as 12 encoder + 12 decoder; the speech frontend is a stub
(input_specs provides precomputed frame embeddings), per the assignment.
Vocab 256206 padded to 256256 for TP divisibility.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    n_enc_layers=12, n_dec_layers=12, d_model=1024, n_heads=16, n_kv=16,
    d_ff=8192, vocab=256206, head_dim=64, norm="layernorm", mlp="gelu",
    rope_theta=1e4, frontend="frames", dtype="bfloat16", remat=True,
    dp_strategy="bk", prefill_last_only=True)
