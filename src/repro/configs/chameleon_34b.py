"""Chameleon-34B [arXiv:2405.09818; unverified] — early-fusion VLM.

VQ image tokens share the 65536 vocab, so backbone inputs are token ids;
the VQ tokenizer frontend is a stub per the assignment.  QK-norm on.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv=8, d_ff=22016, vocab=65536, head_dim=128,
    norm="layernorm", mlp="swiglu", qk_norm=True, rope_theta=1e4,
    dtype="bfloat16", remat=True, fsdp=True, dp_strategy="bk",
    prefill_last_only=True)
