"""DeepSeek-V3-671B [arXiv:2412.19437; hf] — MLA + 256-expert MoE top-8.

MLA: q_lora 1536, kv_lora 512, rope 64, nope 128, v 128 over 128 heads.
1 shared + 256 routed experts (top-8), per-expert hidden 2048.  The MTP
auxiliary head is omitted (next-token objective only; DESIGN.md).  The
leading dense-FFN layers of the reference model are simplified to MoE
throughout (DESIGN.md §deviations).  FSDP + remat mandatory at this size.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv=128, d_ff=2048, vocab=129280, norm="rmsnorm",
    mlp="swiglu", n_experts=256, n_shared_experts=1, topk=8,
    capacity_factor=2.0, mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128, rope_theta=1e4,
    dtype="bfloat16", remat=True, fsdp=True, moe_impl="gather",
    dp_strategy="ghost", prefill_last_only=True)
