"""xLSTM-125M [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

d_ff=0 per the assignment: blocks carry their own projection factor.
slstm_every=4: one sLSTM block per 4 (3 mLSTM + 1 sLSTM), 12 layers total.
Sub-quadratic (recurrent state) -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv=4, d_ff=0, vocab=50304, norm="rmsnorm", slstm_every=4,
    ssm_expand=2, ssm_conv=4, dtype="bfloat16", subquadratic=True,
    dp_strategy="bk", prefill_last_only=True)
