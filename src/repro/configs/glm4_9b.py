"""GLM-4-9B [hf:THUDM/glm-4-9b; hf] — dense, GQA kv=2, RoPE."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096, n_heads=32,
    n_kv=2, d_ff=13696, vocab=151552, head_dim=128, norm="rmsnorm",
    mlp="swiglu", rope_theta=1e4, dtype="bfloat16", remat=True, fsdp=True,
    dp_strategy="bk", prefill_last_only=True)
