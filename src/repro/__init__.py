"""repro: per-example gradients (Rochette, Manoel & Tramel 2019) as a
pod-scale JAX differential-privacy training framework.

Public surface:
  PrivacyEngine    — plan-first DP-SGD: make private once, step many;
                     inspect with engine.explain(), serialize plans with
                     ExecPlan.to_json()/from_json()
  repro.core       — PEG strategies (naive/multi/crb/ghost/bk/auto),
                     DP-SGD, the ExecPlan planner, RDP accounting
  repro.models     — taps-enabled model zoo (LMs, MoE, SSM, enc-dec, CNNs)
  repro.kernels    — Pallas TPU kernels (+ refs)
  repro.configs    — assigned architecture configs
  repro.launch     — production mesh, sharding rules, dry-run, train, serve
"""
__version__ = "2.0.0"

from repro.core import (DPConfig, ExecPlan, NormCfg, PrivacyAccountant,
                        PrivacyEngine, Tapper, clipped_grad_sum, dp_gradient,
                        ghost_norms, per_example_grads)

__all__ = ["DPConfig", "ExecPlan", "NormCfg", "PrivacyAccountant",
           "PrivacyEngine", "Tapper", "clipped_grad_sum", "dp_gradient",
           "ghost_norms", "per_example_grads", "__version__"]
