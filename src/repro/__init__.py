"""repro: per-example gradients (Rochette, Manoel & Tramel 2019) as a
pod-scale JAX differential-privacy training framework.

Public surface:
  repro.core       — PEG strategies (naive/multi/crb/ghost/bk), DP-SGD,
                     RDP privacy accounting
  repro.models     — taps-enabled model zoo (LMs, MoE, SSM, enc-dec, CNNs)
  repro.kernels    — Pallas TPU kernels (+ refs)
  repro.configs    — assigned architecture configs
  repro.launch     — production mesh, sharding rules, dry-run, train, serve
"""
__version__ = "1.0.0"

from repro.core import (DPConfig, PrivacyAccountant, Tapper, clipped_grad_sum,
                        dp_gradient, ghost_norms, per_example_grads)

__all__ = ["DPConfig", "PrivacyAccountant", "Tapper", "clipped_grad_sum",
           "dp_gradient", "ghost_norms", "per_example_grads", "__version__"]
