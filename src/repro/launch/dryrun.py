import os
import sys
if not any(a == "--plan-json" or a.startswith("--plan-json=")
           for a in sys.argv):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The lines above MUST run before any other import: jax locks the device
# count at first initialization.  512 host devices back the production
# meshes (16x16 single-pod, 2x16x16 multi-pod).  The --plan-json smoke
# mode runs eagerly on default devices and skips the mesh entirely.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, per device: HLO FLOPs and bytes
(``compiled.cost_analysis()``), the memory footprint
(``compiled.memory_analysis()``), and collective-traffic bytes parsed from
the post-SPMD compiled HLO (with best-effort while-loop trip-count
multipliers, since collectives inside a layer scan execute once per
layer).  Results append incrementally to a JSON file consumed by
``benchmarks/roofline.py``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
        --shape train_4k --mesh both --out results/dryrun.json
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import DPConfig, NormCfg
from repro.core.clipping import dp_gradient
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model
from repro.optim import adamw_init, adamw_update

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo: str):
    """-> list of (comp_name, [lines]); entry computation flagged."""
    comps, cur_name, cur_lines = [], None, []
    for line in hlo.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            if cur_name is not None:
                comps.append((cur_name, cur_lines))
            nm = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
            cur_name = nm.group(1) if nm else "?"
            cur_lines = []
            if line.startswith("ENTRY"):
                cur_name = "__entry__:" + cur_name
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        comps.append((cur_name, cur_lines))
    return comps


def _while_multipliers(comps):
    """Transitive execution-count multipliers per computation (entry=1,
    while bodies x trip count; nested loops multiply)."""
    names = {name.split(":", 1)[-1]: lines for name, lines in comps}
    whiles = []  # (parent_comp, body, cond, trip)
    for name, lines in comps:
        clean = name.split(":", 1)[-1]
        for line in lines:
            m = re.search(r"\bwhile\(.*?condition=%?([\w.\-]+), "
                          r"body=%?([\w.\-]+)", line)
            if m:
                cond, body = m.group(1), m.group(2)
            else:
                m = re.search(r"\bwhile\(.*?body=%?([\w.\-]+), "
                              r"condition=%?([\w.\-]+)", line)
                if not m:
                    continue
                body, cond = m.group(1), m.group(2)
            trip = 1
            if cond in names:
                consts = [int(x) for x in re.findall(
                    r"constant\((\d+)\)", "\n".join(names[cond]))]
                if consts:
                    trip = max(consts)
            whiles.append((clean, body, trip))

    mult = {}
    for name, _ in comps:
        if name.startswith("__entry__:"):
            mult[name.split(":", 1)[1]] = 1
    for _ in range(12):  # fixpoint over nesting depth
        changed = False
        for parent, body, trip in whiles:
            if parent in mult:
                v = mult[parent] * trip
                if mult.get(body) != v:
                    mult[body] = v
                    changed = True
        if not changed:
            break
    return mult


def parse_collectives(hlo: str) -> dict:
    """Sum per-device result bytes of every collective, multiplying ops in
    while-body computations by the (transitively resolved) trip counts --
    collectives inside a layer scan execute once per layer per microbatch.

    The result shape is the traffic proxy (HLO operands are name-only
    references): exact for all-reduce/all-to-all/permute, the gathered size
    for all-gather (~= ring traffic), the pre-reduce sum for
    reduce-scatter.
    """
    comps = _split_computations(hlo)
    mult = _while_multipliers(comps)

    out = {c: {"count": 0, "bytes": 0} for c in COLLECTIVES}
    for name, lines in comps:
        clean = name.split(":", 1)[-1]
        m = mult.get(clean, 1)
        for line in lines:
            for op in COLLECTIVES:
                if f" {op}(" in line or f" {op}-start(" in line:
                    left = line.split(f" {op}", 1)[0]
                    if "=" in left:
                        left = left.split("=", 1)[1]
                    b = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(left))
                    out[op]["count"] += m
                    out[op]["bytes"] += b * m
                    break
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


_DEF_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*"
                        r"([\w\-]+)\(")
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "while",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}


def parse_hlo_costs(hlo: str) -> dict:
    """Per-device FLOPs and HBM bytes from the scheduled HLO, with
    while-loop trip multipliers (XLA's ``cost_analysis()`` counts loop
    bodies once, which hides everything inside a layer scan).

    FLOPs: matmuls (``dot``: 2·|out|·K from the lhs contracting dims) and
    convolutions (2·|out|·|rhs|/O).  Elementwise FLOPs are ignored —
    matmul-dominant workloads, standard MFU-numerator convention.

    Bytes: Σ over scheduled top-level ops of (result + operand) bytes in
    the entry / while computations — fusion-internal values never touch
    HBM and are excluded by construction.
    """
    comps = _split_computations(hlo)
    mult = _while_multipliers(comps)

    # local (own-loop) trip count per body: tensors inside a scan body
    # whose leading dim equals the trip count are per-step *slices* of
    # loop-invariant stacks (scan xs / ys buffers) — count 1/trip of them.
    local_trip: dict[str, int] = {}
    names_l = {name.split(":", 1)[-1]: lines for name, lines in comps}
    for name, lines in comps:
        for line in lines:
            m = re.search(r"\bwhile\(.*?condition=%?([\w.\-]+), "
                          r"body=%?([\w.\-]+)", line)
            if not m:
                continue
            cond, body = m.group(1), m.group(2)
            trip = 1
            if cond in names_l:
                consts = [int(x) for x in re.findall(
                    r"constant\((\d+)\)", "\n".join(names_l[cond]))]
                if consts:
                    trip = max(consts)
            local_trip[body] = max(local_trip.get(body, 1), trip)

    # global symbol table: value name -> list of (dtype, dims) shapes
    shapes: dict[str, list] = {}
    for _, lines in comps:
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            nm, rest = dm.group(1), dm.group(2)
            om = _OPNAME_RE.match(rest)
            type_seg = rest[: om.start(1)] if om else rest.split(" ", 1)[0]
            shapes[nm] = _SHAPE_RE.findall(type_seg)

    def _tensor_bytes(ss, trip: int) -> float:
        total = 0.0
        for d, s in ss:
            b = _shape_bytes(d, s)
            dims = [int(x) for x in s.split(",") if x]
            if trip > 1 and dims and dims[0] == trip:
                b = b / trip       # per-step slice of a stacked buffer
            total += b
        return total

    flops = 0.0
    bytes_ = 0.0
    for name, lines in comps:
        clean = name.split(":", 1)[-1]
        is_entry = name.startswith("__entry__:")
        if not (is_entry or clean in mult):
            continue  # fusion bodies etc. are accounted at their call site
        m = mult.get(clean, 1)
        lt = local_trip.get(clean, 1)
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rest = dm.group(2)
            om = _OPNAME_RE.match(rest)
            if not om:
                continue
            op = om.group(1)
            if op in _SKIP_OPS:
                continue
            type_seg = rest[: om.start(1)]
            res_shapes = _SHAPE_RE.findall(type_seg)
            res_bytes = _tensor_bytes(res_shapes, lt)
            args_seg = rest[om.end(0):].split(")", 1)[0]
            operands = re.findall(r"%([\w.\-]+)", args_seg)
            opd_bytes = sum(_tensor_bytes(shapes.get(o, []), lt)
                            for o in operands)
            bytes_ += (res_bytes + opd_bytes) * m

            if op == "dot" and operands:
                out_elems = 1
                for d, s in res_shapes:
                    for x in s.split(","):
                        if x:
                            out_elems *= int(x)
                cdm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                k = 1
                lhs_shapes = shapes.get(operands[0], [])
                if cdm and lhs_shapes:
                    dims = [int(x) for x in cdm.group(1).split(",") if x]
                    lhs_dims = [int(x) for x in lhs_shapes[0][1].split(",")
                                if x]
                    for d in dims:
                        if d < len(lhs_dims):
                            k *= lhs_dims[d]
                flops += 2.0 * out_elems * k * m
            elif op == "convolution" and len(operands) >= 2:
                out_elems = 1
                for d, s in res_shapes:
                    for x in s.split(","):
                        if x:
                            out_elems *= int(x)
                rhs_shapes = shapes.get(operands[1], [])
                if rhs_shapes:
                    rdims = [int(x) for x in rhs_shapes[0][1].split(",")
                             if x]
                    o = max(rdims) if rdims else 1
                    per_out = 1
                    for x in rdims:
                        per_out *= x
                    # heuristic: output-feature dim is the rhs dim present
                    # in the result shape; fall back to dim 0.
                    o = rdims[0] if rdims else 1
                    flops += 2.0 * out_elems * (per_out / max(o, 1)) * m
    return {"flops": flops, "bytes": bytes_}



# ---------------------------------------------------------------------------


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()``: newer jax returns one dict,
    older releases a per-device list of dicts (or None pre-compile)."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def abstract_params(model):
    axes_box = []

    def params_only(k):
        params, axes = model.init(k)
        axes_box.append(axes)
        return params

    sds = jax.eval_shape(params_only, jax.random.PRNGKey(0))
    return sds, axes_box[0]


def cache_sharding(cfg, cache_sds, mesh, batch: int):
    """Heuristic cache specs: shard the batch dim over the data axes and an
    exact-n_kv-heads dim over the model axis when divisible."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    data_axes = data_axes if len(data_axes) > 1 else data_axes[0]
    model_size = mesh.shape["model"]

    def spec(leaf):
        dims, used_b, used_m = [], False, False
        for d in leaf.shape:
            if not used_b and batch > 1 and d == batch:
                dims.append(data_axes)
                used_b = True
            elif (not used_m and cfg.n_kv and d == cfg.n_kv
                  and d % model_size == 0):
                dims.append("model")
                used_m = True
            else:
                dims.append(None)
        while dims and dims[-1] is None:
            dims.pop()
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(spec, cache_sds)


def plan_collectives(model, params_sds, bspec, dpc, mesh) -> dict:
    """Planner-predicted per-layer collective bytes for a train cell —
    the analytic counterpart of the post-SPMD HLO collectives parsed from
    the compiled module, from one shape-only probe."""
    from repro.core import costmodel

    plan = costmodel.get_plan(model.apply, params_sds, bspec,
                              mesh=mesh, **dpc.planner_opts())
    return {
        "mesh": costmodel.format_mesh(tuple(plan.mesh)),
        "fingerprint": plan.fingerprint,
        "per_layer_bytes": {n: lp.coll_bytes
                            for n, lp in plan.layers.items()},
        "total_bytes": plan.total_coll_bytes,
    }


def build_cell(arch: str, shape_name: str, mesh, *, microbatches=None,
               overrides: dict | None = None, dp_overrides: dict | None = None):
    """Returns (step_fn, example_args_with_shardings, donate, info) for a
    cell; ``info`` carries the planner's predicted per-layer collective
    bytes for train cells.

    ``overrides``: ModelConfig fields (hillclimb knobs, e.g.
    prefill_last_only=True, moe_impl="einsum", remat=False).
    ``dp_overrides``: DPConfig fields (strategy, norm_method, embed_norm,
    microbatches).
    """
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    params_sds, axes = abstract_params(model)
    pshard = shd.param_sharding(axes, mesh, fsdp=cfg.fsdp,
                                shapes_tree=params_sds)
    params_in = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_sds, pshard)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        m = microbatches or (16 if cfg.fsdp else 8)
        dpkw = dict(l2_clip=1.0, noise_multiplier=1.0,
                    strategy=cfg.dp_strategy, microbatches=m)
        normkw = dict(embed="gram")  # gram = paper-faithful baseline
        # --dp-set accepts both new NormCfg names (dense/embed/conv/
        # conv_impl) and the legacy knob names.
        _legacy = {"norm_method": "dense", "embed_norm": "embed",
                   "conv_norm": "conv"}
        for k, v in (dp_overrides or {}).items():
            k = _legacy.get(k, k)
            if k in ("dense", "embed", "conv", "conv_impl", "mem_budget"):
                normkw[k] = "auto" if v is None else v
            else:
                dpkw[k] = v
        dpc = DPConfig(norm=NormCfg(**normkw), **dpkw)

        def train_step(params, opt, batch, key):
            loss, grad, aux = dp_gradient(model.apply, params, batch,
                                          cfg=dpc, key=key)
            params, opt = adamw_update(grad, opt, params, lr=1e-4,
                                       weight_decay=0.01)
            return params, opt, loss, aux["clip_fraction"]

        opt_sds = jax.eval_shape(adamw_init, params_sds)
        opt_shard = jax.tree.map(
            lambda l: (NamedSharding(mesh, P()) if l.ndim == 0 else None),
            opt_sds)
        # moments share the parameter shardings (ZeRO via FSDP specs)
        opt_in = {
            "m": jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=sh), opt_sds["m"], pshard),
            "v": jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=sh), opt_sds["v"], pshard),
            "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=repl),
        }
        bspec = model.train_input_specs(shape)
        bshard = shd.batch_sharding(bspec, mesh)
        batch_in = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            bspec, bshard)
        key_in = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=repl)
        try:
            info = {"dp_plan": plan_collectives(model, params_sds, bspec,
                                                dpc, mesh)}
        except Exception as e:          # advisory: never fail the cell
            info = {"dp_plan": {"error": f"{type(e).__name__}: {e}"}}
        return train_step, (params_in, opt_in, batch_in, key_in), (0, 1), \
            info

    if shape.kind == "prefill":
        specs = model.prefill_input_specs(shape)
        bshard = shd.batch_sharding(specs, mesh)
        args = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            specs, bshard)

        if cfg.family == "encdec":
            def prefill_step(params, src, tokens):
                logits, cache = model.prefill(params, src, tokens,
                                              max_len=shape.seq_len // 2)
                return jnp.argmax(logits, -1).astype(jnp.int32), cache

            return prefill_step, (params_in, args["src_frames"],
                                  args["tokens"]), (), {}

        def prefill_step(params, tokens):
            logits, cache = model.prefill(params, tokens,
                                          max_len=shape.seq_len)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        return prefill_step, (params_in, args["tokens"]), (), {}

    # decode
    specs = model.decode_input_specs(shape)
    cshard = cache_sharding(cfg, specs["cache"], mesh, shape.global_batch)
    cache_in = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs["cache"], cshard)
    tok_in = jax.ShapeDtypeStruct(
        specs["tokens"].shape, specs["tokens"].dtype,
        sharding=(NamedSharding(mesh, P()) if shape.global_batch == 1 else
                  jax.tree.leaves(shd.batch_sharding(
                      {"t": specs["tokens"]}, mesh))[0]))

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    return serve_step, (params_in, cache_in, tok_in), (1,), {}


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, save_hlo=None,
             overrides=None, dp_overrides=None):
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    with shd.mesh_rules(mesh):
        step, args, donate, info = build_cell(arch, shape_name, mesh,
                                              overrides=overrides,
                                              dp_overrides=dp_overrides)
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ca = cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    costs = parse_hlo_costs(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok",
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "flops_parsed": costs["flops"],
        "bytes_parsed": costs["bytes"],
        "flops_per_device": ca.get("flops"),
        "bytes_per_device": ca.get("bytes accessed"),
        "transcendentals": ca.get("transcendentals"),
        "memory": None if ma is None else {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "collectives": coll,
        "hlo_chars": len(hlo),
    }
    if info.get("dp_plan"):
        rec["dp_plan_collectives"] = info["dp_plan"]
    return rec


def cells_for(arch: str):
    cfg = get_config(arch)
    for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if s == "long_500k" and not cfg.subquadratic:
            continue  # full-attention archs skip 512k decode (DESIGN.md)
        yield s


def _plan_smoke_batch(cfg, batch: int, seq: int):
    rng = np.random.RandomState(0)
    if cfg.family == "cnn":
        return {"img": jnp.asarray(
                    rng.randn(batch, 3, cfg.img_size, cfg.img_size),
                    jnp.float32),
                "label": jnp.asarray(rng.randint(0, cfg.n_classes, (batch,)))}
    return {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (batch, seq))),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab, (batch, seq)))}


def plan_smoke(path: str, arch: str, batch: int = 2, seq: int = 16) -> int:
    """Serialized-plan round trip across processes.

    First invocation (file absent): plan via PrivacyEngine, run one eager
    clipped-grad step, write the plan + per-leaf gradient digests.  Second
    invocation (file present, i.e. a fresh process): load the plan store,
    then verify the engine executes with ZERO probes and reproduces the
    stored gradients bit-for-bit.
    """
    from repro.core import PrivacyEngine, costmodel
    from repro.core.tapper import STATS

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch_d = _plan_smoke_batch(cfg, batch, seq)
    dp = DPConfig(l2_clip=1.0, strategy="auto")

    def digests(grad):
        import hashlib
        return {jax.tree_util.keystr(kp):
                hashlib.sha256(np.ascontiguousarray(
                    np.asarray(leaf)).tobytes()).hexdigest()
                for kp, leaf in jax.tree_util.tree_leaves_with_path(grad)}

    if os.path.exists(path):
        n = costmodel.load_plan_store(path)
        engine = PrivacyEngine(model.apply, params, batch_d, dp=dp)
        STATS.reset()
        _, grad, _ = engine.noisy_grad(params, batch_d)
        snap = STATS.snapshot()
        assert snap["probes"] == 0, \
            f"plan store missed — model was re-probed: {snap}"
        with open(path) as f:
            want = json.load(f)["grad_digest"]
        got = digests(grad)
        bad = {k: (want[k], got.get(k)) for k in want if want[k] != got.get(k)}
        assert not bad, f"loaded-plan gradients differ: {bad}"
        print(f"plan smoke OK: {n} plan(s) loaded, probes=0, "
              f"{len(got)} gradient digests identical")
    else:
        engine = PrivacyEngine(model.apply, params, batch_d, dp=dp)
        _, grad, _ = engine.noisy_grad(params, batch_d)
        costmodel.save_plan_store(path, [engine.plan()],
                                  extra={"grad_digest": digests(grad)})
        print(f"plan smoke: wrote plan + digests to {path} "
              f"(fingerprint {engine.plan().fingerprint})")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan-json", default=None,
                    help="serialized-ExecPlan smoke: write plan + gradient "
                         "digests if the file is absent, else load it and "
                         "verify probe-free, bit-identical execution")
    ap.add_argument("--plan-arch", default="llama3.2-1b")
    ap.add_argument("--plan-batch", type=int, default=2)
    ap.add_argument("--plan-seq", type=int, default=16)
    ap.add_argument("--arch", nargs="*", default=ARCH_IDS)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--set", nargs="*", default=[], metavar="K=V",
                    help="ModelConfig overrides, e.g. prefill_last_only=True")
    ap.add_argument("--dp-set", nargs="*", default=[], metavar="K=V",
                    help="DPConfig overrides, e.g. strategy=bk "
                         "embed_norm=segsum norm_method=stream")
    ap.add_argument("--calibration", default=None,
                    help="measured-cost calibration JSON to pre-register "
                         "before planning (see `python -m "
                         "benchmarks.kernels_bench --calibrate-only`); "
                         "unusable blobs fall back to analytic constants "
                         "with a named warning")
    args = ap.parse_args()

    if args.calibration:
        from repro import calibrate
        calib = calibrate.load_or_fallback(args.calibration)
        if calib is not None:
            calibrate.register(calib)
            print(f"[calibrate] registered {calib.digest()} "
                  f"(source={calib.source})")

    if args.plan_json:
        return plan_smoke(args.plan_json, args.plan_arch,
                          batch=args.plan_batch, seq=args.plan_seq)

    def _parse_kv(items):
        out = {}
        for kv in items:
            k, v = kv.split("=", 1)
            if v in ("True", "False"):
                v = v == "True"
            else:
                try:
                    v = int(v)
                except ValueError:
                    try:
                        v = float(v)
                    except ValueError:
                        pass
            out[k] = v
        return out

    overrides = _parse_kv(args.set)
    dp_overrides = _parse_kv(args.dp_set)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if args.skip_existing and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"}

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    for arch in args.arch:
        shapes = args.shape or list(cells_for(arch))
        for shape in shapes:
            for mk in meshes:
                if (arch, shape, mk) in done:
                    continue
                print(f"=== {arch} x {shape} x {mk}", flush=True)
                try:
                    hlo_path = None
                    if args.hlo_dir:
                        os.makedirs(args.hlo_dir, exist_ok=True)
                        hlo_path = os.path.join(
                            args.hlo_dir, f"{arch}_{shape}_{mk}.hlo")
                    rec = run_cell(arch, shape, mk, save_hlo=hlo_path,
                                   overrides=overrides,
                                   dp_overrides=dp_overrides)
                    print(f"    ok: compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"coll={rec['collectives']['total_bytes']:.3e}B",
                          flush=True)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mk,
                           "status": "fail", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"    FAIL: {rec['error']}", flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"])
                           != (arch, shape, mk)]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"done: {n_ok}/{len(results)} ok")


if __name__ == "__main__":
    main()
