"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Target: TPU v5e pods, 256 chips each, mesh
(data=16, model=16); the multi-pod mesh adds a leading "pod" axis that the
launchers treat as an extra pure-data axis.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(jax.devices())} — "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh(model_par: int = 1):
    """Small mesh over whatever devices exist (tests, CPU training)."""
    n = len(jax.devices())
    data = n // model_par
    return jax.make_mesh((data, model_par), ("data", "model"),
                         devices=jax.devices()[: data * model_par])
