"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Target: TPU v5e pods, 256 chips each, mesh
(data=16, model=16); the multi-pod mesh adds a leading "pod" axis that the
launchers treat as an extra pure-data axis.
"""
from __future__ import annotations

import math
import os

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(jax.devices())} — "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh(model_par: int = 1):
    """Small mesh over whatever devices exist (tests, CPU training)."""
    n = len(jax.devices())
    data = n // model_par
    return jax.make_mesh((data, model_par), ("data", "model"),
                         devices=jax.devices()[: data * model_par])


def force_host_device_count_for(argv):
    """Pre-main hook for CLI entry points: when ``argv`` carries a
    ``--mesh data:N`` spec and ``XLA_FLAGS`` is unset, force the host
    platform to N devices.  Must run before jax initializes its backend
    (merely having imported jax is fine — the device count locks at
    first use)."""
    if "XLA_FLAGS" in os.environ:
        return
    specs = []
    for i, a in enumerate(argv):
        if a.startswith("--mesh="):
            specs.append(a.split("=", 1)[1])
        elif a == "--mesh":
            # Multi-valued form (dpcheck lanes): consume every value up
            # to the next flag; the host must cover the *largest* lane.
            j = i + 1
            while j < len(argv) and not argv[j].startswith("--"):
                specs.append(argv[j])
                j += 1
    n = max((math.prod(int(p.split(":")[1]) for p in s.split(",")
                       if ":" in p)
             for s in specs), default=1)
    if n <= 1:
        return
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n}"


def make_mesh_from_spec(spec: str):
    """Build a live ``jax.sharding.Mesh`` from a planner mesh spec like
    ``"data:8"`` or ``"data:4,model:2"`` (see ``costmodel.mesh_axes``) over
    this process's devices.  The device count must cover the mesh; on a
    CPU host set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    before any jax import."""
    from repro.core.costmodel import format_mesh, mesh_axes

    axes = mesh_axes(spec)
    if not axes:
        return None
    shape = tuple(s for _, s in axes)
    names = tuple(n for n, _ in axes)
    n = math.prod(shape)
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"mesh {format_mesh(axes)} needs {n} devices, have "
            f"{len(jax.devices())} — on CPU hosts set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            "any jax import")
    return jax.make_mesh(shape, names, devices=jax.devices()[:n])
