"""Logical-axis sharding rules (t5x-style) + activation constraints.

Models annotate activations with *logical* axes ("batch", "seq", "embed",
"heads", "mlp", "vocab", "expert", "kv"); parameters carry logical axis
tuples built at init time.  A rules table maps logical axes to mesh axes.
Outside a mesh context every annotation is a no-op, so models stay
mesh-agnostic.

``PrivacyEngine(param_axes=...)`` routes its params — and the adamw/sgdm
optimizer moments, which inherit the param layout — through
:func:`param_sharding` whenever the mesh has a ``model`` axis, so the 2D
(data × model) private step executes tensor-sharded end to end; the
``shapes_tree`` divisibility fallback is what lets odd-width heads stay
replicated next to a sharded trunk (see ``core.engine._step_shardings``).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Default production rules.  "batch" maps to all pure-data axes; FSDP
# additionally shards the "embed"/"ff_in" param axes over the data axes.
ACT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "state": None,
    "frames": None,
}

PARAM_RULES = {
    "embed": None,
    "heads": "model",
    "kv": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "layer": None,
    "conv_k": None,
    "state": None,
    "qrank": None,
    "kvrank": None,
}

FSDP_PARAM_RULES = dict(PARAM_RULES, embed=("pod", "data"))


def _axes_to_spec(axes: tuple, rules: dict, mesh: Mesh,
                  shape: tuple | None = None) -> P:
    names = []
    used = set()
    for i, ax in enumerate(axes):
        m = rules.get(ax) if ax is not None else None
        # Drop mesh axes not present in this mesh, already used, or not
        # dividing the dimension.
        if m is None:
            names.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(x for x in ms if x in mesh.axis_names and x not in used)
        if shape is not None and ms:
            total = 1
            for x in ms:
                total *= mesh.shape[x]
            if shape[i] % total != 0:
                # try the single largest dividing prefix
                ms = tuple(x for x in ms
                           if shape[i] % mesh.shape[x] == 0)[:1]
                if ms and shape[i] % mesh.shape[ms[0]] != 0:
                    ms = ()
        used.update(ms)
        if not ms:
            names.append(None)
        elif len(ms) == 1:
            names.append(ms[0])
        else:
            names.append(ms)
    while names and names[-1] is None:
        names.pop()
    return P(*names)


@contextlib.contextmanager
def mesh_rules(mesh: Mesh, act_rules: dict | None = None):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, act_rules or ACT_RULES)
    try:
        yield
    finally:
        _state.ctx = prev


def shard_act(x, *axes):
    """Constrain an activation's sharding if inside a mesh_rules context."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = _axes_to_spec(axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_sharding(axes_tree, mesh: Mesh, *, fsdp: bool = False,
                   shapes_tree=None):
    """Map a logical-axes pytree to NamedShardings.  With ``shapes_tree``
    (parallel pytree of array/SDS leaves) mesh axes that do not divide the
    dimension are dropped instead of erroring (e.g. 4 heads on a 16-way
    model axis stay replicated)."""
    rules = FSDP_PARAM_RULES if fsdp else PARAM_RULES
    is_axes = lambda x: isinstance(x, tuple)
    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(mesh, _axes_to_spec(axes, rules, mesh)),
            axes_tree, is_leaf=is_axes)
    return jax.tree.map(
        lambda axes, leaf: NamedSharding(
            mesh, _axes_to_spec(axes, rules, mesh, tuple(leaf.shape))),
        axes_tree, shapes_tree, is_leaf=is_axes)


def batch_sharding(batch_abstract, mesh: Mesh):
    """Shard every batch leaf's leading axis over the data axes (the same
    axis-name vocabulary the planner's cost model uses)."""
    from repro.core.costmodel import DATA_AXIS_NAMES

    data_axes = tuple(a for a in DATA_AXIS_NAMES if a in mesh.axis_names)
    if not data_axes:
        raise ValueError(
            f"mesh axes {mesh.axis_names} contain no data-parallel axis "
            f"(one of {DATA_AXIS_NAMES}) to shard the batch over")
    spec = P(data_axes if len(data_axes) > 1 else data_axes[0])

    def mk(leaf):
        return NamedSharding(mesh, spec)

    return jax.tree.map(mk, batch_abstract)
