"""Launchers: production mesh, sharding rules, dry-run, train, serve."""
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.sharding import (batch_sharding, mesh_rules,
                                   param_sharding, shard_act)

__all__ = ["make_host_mesh", "make_production_mesh", "batch_sharding",
           "mesh_rules", "param_sharding", "shard_act"]
