"""Batched serving driver: continuous batching over a request queue.

Requests (token prompts) are grouped into fixed-size batches; each batch
is prefilled once and decoded step-by-step with the KV/recurrent cache.
This is the small-scale twin of the decode_32k/long_500k dry-run cells.

``--dp-plan`` pre-loads a serialized ExecPlan store (written by
``launch/train.py --plan-json`` or ``launch/dryrun.py --plan-json``) so
that any DP-gradient work colocated with serving — online fine-tuning,
per-request gradient attribution — hits the store by fingerprint and
never pays a model probe in the serving process.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --n-requests 8 --batch 4 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.registry import build_model


def generate_batch(model, params, prompts, *, max_len: int, gen: int,
                   cfg):
    """prompts (B, Tp) -> generated tokens (B, gen)."""
    if cfg.family == "encdec":
        B = prompts.shape[0]
        src = jnp.zeros((B, prompts.shape[1], cfg.d_model), jnp.float32)
        logits, cache = model.prefill(params, src, prompts, max_len=max_len)
    else:
        logits, cache = model.prefill(params, prompts, max_len=max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    @jax.jit
    def step(cache, tok):
        logits, cache = model.decode_step(params, cache, tok)
        return cache, jnp.argmax(logits, -1).astype(jnp.int32)

    out = [tok]
    for _ in range(gen - 1):
        cache, tok = step(cache, tok)
        out.append(tok)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dp-plan", default=None,
                    help="serialized ExecPlan store to pre-load (skips the "
                         "planning probe for colocated DP-gradient work)")
    ap.add_argument("--calibration", default=None,
                    help="measured-cost calibration JSON to pre-register "
                         "(see `python -m benchmarks.kernels_bench "
                         "--calibrate-only`); unusable blobs fall back to "
                         "analytic constants with a named warning")
    args = ap.parse_args(argv)

    if args.calibration:
        from repro import calibrate
        calib = calibrate.load_or_fallback(args.calibration)
        if calib is not None:
            calibrate.register(calib)
            print(f"[calibrate] registered {calib.digest()} "
                  f"(source={calib.source})")
    if args.dp_plan:
        from repro.core import costmodel
        n = costmodel.load_plan_store(args.dp_plan)
        print(f"[dp] pre-loaded {n} exec plan(s) from {args.dp_plan}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    queue = [rng.randint(0, cfg.vocab, args.prompt_len).astype(np.int32)
             for _ in range(args.n_requests)]

    t0 = time.time()
    done = 0
    while queue:
        batch = queue[: args.batch]
        queue = queue[args.batch:]
        while len(batch) < args.batch:        # pad the final batch
            batch.append(batch[-1])
        prompts = jnp.asarray(np.stack(batch))
        toks = generate_batch(model, params, prompts,
                              max_len=args.prompt_len + args.gen,
                              gen=args.gen, cfg=cfg)
        done += len(batch)
        print(f"batch done: {toks.shape} sample={np.asarray(toks[0, :8])}")
    dt = time.time() - t0
    print(f"served {done} requests in {dt:.2f}s "
          f"({done * args.gen / dt:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
