"""End-to-end DP training driver with checkpoint/restart fault tolerance.

Runs on whatever devices exist (CPU here, a pod in production — the same
code path: the mesh is just bigger).  The loop is plan → step → account:
one PrivacyEngine owns the ExecPlan, the jitted private step, and the
accountant; checkpointing, the straggler monitor, and chaos-monkey fault
injection wrap around it.  ``--mesh data:8`` plans mesh-aware (per-layer
collective-bytes costs, topology-keyed fingerprint — the plan table gains
a ``coll MB`` column) and runs the private step sharded over the data
axes; on a CPU host the device count is forced to match before jax loads.

Preemption safety: noise keys come from the engine's deterministic
stream (``fold_in(PRNGKey(--run-seed), step)``), and checkpoints persist
the full :class:`~repro.checkpoint.DPTrainState` — params, optimizer,
cross-step clip state, the accountant ledger, the plan fingerprint, and
the monitor — so a killed run resumes bit-identically (the differential
proof lives in tests/test_resume_equivalence.py).  Resuming with fewer
devices than the checkpoint's mesh re-plans automatically onto the
surviving topology while the ledger and noise stream continue unbroken.
``--chaos p`` drills the whole path with seeded per-step failures.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 50 --batch 8 --noise 0.8 --clip 1.0 \
        --ckpt-dir /tmp/ckpt --fail-at 20 --chaos 0.05 --mesh data:8
"""
from __future__ import annotations

import argparse
import os
import sys

if __name__ == "__main__":
    # A --mesh data:N run on a CPU host needs N devices before the jax
    # backend initializes.
    from repro.launch.mesh import force_host_device_count_for
    force_host_device_count_for(sys.argv)

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, DPTrainState
from repro.configs import get_config
from repro.core import (ClipPolicy, DPConfig, PrivacyAccountant,
                        PrivacyEngine, costmodel)
from repro.data import SyntheticImageDataset, SyntheticLMDataset
from repro.models.registry import build_model
from repro.optim import adamw_init, cosine_schedule
from repro.runtime import ChaosMonkey, StepMonitor, WorkerFailure, \
    elastic_mesh_axes, run_with_restarts


def make_batch_fn(cfg, batch: int, seq: int):
    if cfg.family == "cnn":
        ds = SyntheticImageDataset(cfg.img_size, cfg.n_classes)

        def fn(step):
            idx = (np.arange(batch) + step * batch) % len(ds)
            return ds.batch(idx)
    elif cfg.family == "encdec":
        ds = SyntheticLMDataset(cfg.vocab, seq)

        def fn(step):
            idx = (np.arange(batch) + step * batch) % len(ds)
            b = ds.batch(idx)
            g = np.random.RandomState(step)
            return {"src_frames": g.randn(batch, seq // 2, cfg.d_model)
                    .astype(np.float32),
                    "tokens": b["tokens"][:, : seq // 2],
                    "labels": b["labels"][:, : seq // 2]}
    else:
        ds = SyntheticLMDataset(cfg.vocab, seq)

        def fn(step):
            idx = (np.arange(batch) + step * batch) % len(ds)
            return ds.batch(idx)
    return fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--noise", type=float, default=0.0)
    ap.add_argument("--strategy", default=None,
                    choices=[None, "naive", "multi", "crb", "ghost", "bk",
                             "auto"])
    ap.add_argument("--clip-mode", default="flat",
                    choices=["flat", "per_layer", "stale"],
                    help="clipping policy: flat (exact, default), "
                         "per_layer (per-layer budgets with sum C_l^2 = "
                         "C^2), or stale (lagged coefficients; fused "
                         "single-pass plan, 1 fwd + 1 bwd steady state)")
    ap.add_argument("--clip-budgets", default="uniform",
                    choices=["uniform", "auto"],
                    help="per_layer budget split: uniform, or auto "
                         "(tracked per-layer norm quantiles)")
    ap.add_argument("--microbatches", default=1,
                    type=lambda v: v if v == "auto" else int(v),
                    help="int, or 'auto' to derive from the plan's "
                         "peak-memory estimates")
    ap.add_argument("--mesh", default=None,
                    help="mesh spec, e.g. 'data:8': plan mesh-aware "
                         "(collective-bytes costs, topology-keyed "
                         "fingerprint) and run the step sharded over the "
                         "data axes")
    ap.add_argument("--explain", action="store_true",
                    help="print the per-layer execution plan and exit")
    ap.add_argument("--plan-json", default=None,
                    help="plan cache file: loaded if present (skips the "
                         "probe), written after planning otherwise")
    ap.add_argument("--calibration", default=None,
                    help="'analytic' plans from the analytic constants "
                         "(the explicit opt-out; meshes with model axes "
                         "otherwise auto-measure at first engine init); "
                         "measured cost constants: a calibration JSON "
                         "path (written by `python -m benchmarks."
                         "kernels_bench --calibrate-only`; unusable blobs "
                         "fall back to analytic constants with a named "
                         "warning), or the literal 'measure' to run the "
                         "microbenchmark harness at engine init")
    ap.add_argument("--mispredict-threshold", type=float, default=0.5,
                    help="relative measured-vs-predicted step time "
                         "divergence that triggers an automatic re-plan "
                         "(requires an active calibration and planned "
                         "execution, i.e. strategy auto); <= 0 disables")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--run-seed", type=int, default=0,
                    help="seed of the deterministic noise stream: step "
                         "n's noise key is fold_in(PRNGKey(run_seed), n), "
                         "so a resumed run replays exactly the noise an "
                         "uninterrupted run would draw")
    ap.add_argument("--chaos", type=float, default=0.0,
                    help="chaos drill: per-step failure probability "
                         "(seeded via --chaos-seed, so drills replay)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--restart-backoff", type=float, default=0.0,
                    help="base seconds of the jittered exponential "
                         "restart backoff")
    ap.add_argument("--restart-window", type=float, default=None,
                    help="budget --max-restarts over a sliding window of "
                         "this many seconds instead of the whole run")
    ap.add_argument("--delta", type=float, default=1e-5)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override reduced d_model (e.g. ~100M scale)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model,
                          d_ff=(args.d_model * 4 if cfg.d_ff else 0),
                          head_dim=max(args.d_model // max(cfg.n_heads, 1),
                                       8))
    if args.layers:
        cfg = cfg.replace(n_layers=args.layers)
    model = build_model(cfg)
    # Non-flat clip modes need a per-group coefficient flow: respect an
    # explicit --strategy (DPConfig validates the combination), but only
    # override the model's configured default when it would be invalid.
    strategy = args.strategy or cfg.dp_strategy
    if args.clip_mode != "flat" and args.strategy is None \
            and strategy not in ("auto", "bk"):
        strategy = "auto"
    dpc = DPConfig(l2_clip=args.clip, noise_multiplier=args.noise,
                   strategy=strategy,
                   microbatches=args.microbatches, delta=args.delta,
                   clipping=ClipPolicy(mode=args.clip_mode,
                                       budgets=args.clip_budgets))
    batch_fn = make_batch_fn(cfg, args.batch, args.seq)
    n_data = 1 << 16
    acct = PrivacyAccountant(sampling_rate=args.batch / n_data,
                             noise_multiplier=args.noise)
    chaos = ChaosMonkey(fail_at_steps=args.fail_at, p=args.chaos,
                        seed=args.chaos_seed)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.plan_json and os.path.exists(args.plan_json):
        n = costmodel.load_plan_store(args.plan_json)
        print(f"[plan] loaded {n} plan(s) from {args.plan_json}")

    # Elastic resume: when a checkpoint exists, its mesh is the *intent*;
    # the devices this process actually has are the constraint.  An
    # explicit --mesh wins; otherwise re-plan the checkpoint's mesh onto
    # the surviving devices (same model parallelism, largest feasible
    # data degree) instead of hard-failing on the fingerprint mismatch.
    stored_meta = None
    if ckpt and ckpt.latest_step() is not None:
        stored_meta = ckpt.read_meta()
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh_from_spec
        mesh = make_mesh_from_spec(args.mesh)
        d = costmodel.mesh_data_size(costmodel.mesh_axes(mesh))
        if args.batch % d:
            raise SystemExit(f"--batch {args.batch} not divisible by the "
                             f"mesh's data-parallel degree {d}")
        print(f"[mesh] {costmodel.format_mesh(costmodel.mesh_axes(mesh))} "
              f"over {len(jax.devices())} devices")
    elif stored_meta and stored_meta.get("mesh_axes"):
        from repro.launch.mesh import make_mesh_from_spec
        stored_axes = tuple((n, int(s))
                            for n, s in stored_meta["mesh_axes"])
        live_axes = elastic_mesh_axes(stored_axes, len(jax.devices()),
                                      args.batch)
        if live_axes != stored_axes:
            print(f"[elastic] checkpoint mesh "
                  f"{costmodel.format_mesh(stored_axes)} -> "
                  f"{costmodel.format_mesh(live_axes)} on "
                  f"{len(jax.devices())} surviving devices (re-planning; "
                  f"ledger and noise stream continue)")
        if live_axes:
            mesh = make_mesh_from_spec(
                ",".join(f"{n}:{s}" for n, s in live_axes))
    params0, axes0 = model.init(jax.random.PRNGKey(0))
    # One monitor for the whole run: stragglers (and re-plan events)
    # survive restarts instead of being read off a fresh StepMonitor at
    # the end (and they survive *process* deaths too — the monitor rides
    # in the checkpoint).
    mon = StepMonitor()
    engine = PrivacyEngine(
        model.apply, params0, batch_fn(0), dp=dpc, optimizer="adamw",
        lr=lambda step: cosine_schedule(step, warmup=10, total=args.steps,
                                        peak=args.lr),
        weight_decay=0.01, accountant=acct, mesh=mesh, param_axes=axes0,
        run_seed=args.run_seed, calibration=args.calibration,
        mispredict_threshold=(args.mispredict_threshold
                              if args.mispredict_threshold > 0 else None),
        monitor=mon)
    if engine.calibration is not None:
        print(f"[calibrate] {engine.calibration.digest()} "
              f"(source={engine.calibration.source})")
    # Fixed strategies bypass the planner; don't pay an advisory probe for
    # them unless the user asks.
    if args.explain or dpc.strategy == "auto":
        print(engine.explain())
    if args.explain:
        return []
    if args.plan_json and not os.path.exists(args.plan_json):
        engine.save_plan(args.plan_json)
        print(f"[plan] wrote {args.plan_json}")

    mesh_axes_now = costmodel.mesh_axes(mesh)

    def train_state(params, opt):
        return DPTrainState(
            params=params, opt=opt, clip_state=engine.clip_state_dict(),
            ledger=acct.state_dict(), plan_fingerprint=engine.fingerprint(),
            monitor=mon.state_dict(), run_seed=args.run_seed,
            mesh_axes=mesh_axes_now)

    def segment(restart_count):
        params = params0
        opt = adamw_init(params)
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            st, at = ckpt.restore_state(params, opt, fallback=True)
            if st.run_seed is not None and st.run_seed != args.run_seed:
                raise SystemExit(
                    f"checkpoint noise stream run_seed={st.run_seed} != "
                    f"--run-seed {args.run_seed}: resuming would draw a "
                    f"different noise sequence than the run being resumed")
            if st.plan_fingerprint and \
                    st.plan_fingerprint != engine.fingerprint():
                # A mesh change is the one legitimate fingerprint drift:
                # cross-check by re-keying under the checkpoint's mesh.
                if st.plan_fingerprint != engine.fingerprint(
                        mesh=st.mesh_axes):
                    raise SystemExit(
                        "checkpoint plan fingerprint mismatch beyond the "
                        "mesh: model code, shapes, or DP config changed; "
                        "refusing to resume onto a different mechanism")
            params, opt = st.params, st.opt
            engine.load_clip_state(st.clip_state)
            if st.ledger is not None:
                acct.load_state_dict(st.ledger)
            if st.monitor is not None:
                mon.load_state_dict(st.monitor)
            start = at + 1
            print(f"[restore] resuming from step {start}")
        else:
            # From-scratch (re)start: params go back to params0, so the
            # ledger and cross-step clip state must go back too — a
            # restarted segment that kept counting would overstate ε and
            # clip with another run's lagged norms.
            engine.reset_clip_state()
            acct.reset()
        losses = []
        # First step of a segment (and of each re-planned jit) compiles;
        # its wall-clock says nothing about the steady state, so it is
        # not fed to the mispredict loop.
        skip_observe = True
        for step in range(start, args.steps):
            chaos.maybe_fail(step)
            mon.start()
            batch = jax.tree.map(jnp.asarray, batch_fn(step))
            params, opt, loss, aux = engine.private_step(
                params, opt, batch, step=step)
            dt = mon.stop(step)
            if skip_observe:
                skip_observe = False
            else:
                ev = engine.observe_step_time(dt, step=step)
                if ev is not None:
                    skip_observe = True
                    print(f"[replan] step {step}: measured/predicted "
                          f"{ev.ratio:.2f}x — calibration "
                          f"{ev.old_calibration} -> {ev.new_calibration}, "
                          f"plan {'changed' if ev.plan_changed else 'kept'}")
            losses.append(float(loss))
            if step % 10 == 0 or step == args.steps - 1:
                # Under stale clipping the honest "what did this step
                # apply" metric is the lagged one; under per_layer the
                # scalar is the mean over (layer, example) pairs of the
                # per-layer fractions also present in aux.
                if "clip_fraction_lagged" in aux:
                    clip_msg = (f"clip_frac(lagged) "
                                f"{float(aux['clip_fraction_lagged']):.2f}")
                else:
                    clip_msg = f"clip_frac {float(aux['clip_fraction']):.2f}"
                print(f"step {step:4d} loss {float(loss):.4f} "
                      f"{clip_msg} {dt*1e3:.0f}ms"
                      + (f" [{engine.report()}]" if args.noise else ""))
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_state_async(step, train_state(params, opt))
        if ckpt:
            ckpt.wait()
            ckpt.save_state(args.steps - 1, train_state(params, opt))
        return losses

    losses, restarts = run_with_restarts(
        segment, max_restarts=args.max_restarts,
        backoff_s=args.restart_backoff,
        restart_window_s=args.restart_window)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}), "
          f"restarts={restarts}, stragglers={len(mon.stragglers)}, "
          f"replans={len(mon.replans)}")
    if args.noise:
        print(engine.report())
    return losses


if __name__ == "__main__":
    main()
