"""End-to-end DP training driver with checkpoint/restart fault tolerance.

Runs on whatever devices exist (CPU here, a pod in production — the same
code path: the mesh is just bigger).  The loop is plan → step → account:
one PrivacyEngine owns the ExecPlan, the jitted private step, and the
accountant; checkpointing, the straggler monitor, and chaos-monkey fault
injection wrap around it.  ``--mesh data:8`` plans mesh-aware (per-layer
collective-bytes costs, topology-keyed fingerprint — the plan table gains
a ``coll MB`` column) and runs the private step sharded over the data
axes; on a CPU host the device count is forced to match before jax loads.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 50 --batch 8 --noise 0.8 --clip 1.0 \
        --ckpt-dir /tmp/ckpt --fail-at 20 --mesh data:8
"""
from __future__ import annotations

import argparse
import os
import sys

if __name__ == "__main__":
    # A --mesh data:N run on a CPU host needs N devices before the jax
    # backend initializes.
    from repro.launch.mesh import force_host_device_count_for
    force_host_device_count_for(sys.argv)

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.core import (ClipPolicy, DPConfig, PrivacyAccountant,
                        PrivacyEngine, costmodel)
from repro.data import SyntheticImageDataset, SyntheticLMDataset
from repro.models.registry import build_model
from repro.optim import adamw_init, cosine_schedule
from repro.runtime import ChaosMonkey, StepMonitor, WorkerFailure, \
    run_with_restarts


def make_batch_fn(cfg, batch: int, seq: int):
    if cfg.family == "cnn":
        ds = SyntheticImageDataset(cfg.img_size, cfg.n_classes)

        def fn(step):
            idx = (np.arange(batch) + step * batch) % len(ds)
            return ds.batch(idx)
    elif cfg.family == "encdec":
        ds = SyntheticLMDataset(cfg.vocab, seq)

        def fn(step):
            idx = (np.arange(batch) + step * batch) % len(ds)
            b = ds.batch(idx)
            g = np.random.RandomState(step)
            return {"src_frames": g.randn(batch, seq // 2, cfg.d_model)
                    .astype(np.float32),
                    "tokens": b["tokens"][:, : seq // 2],
                    "labels": b["labels"][:, : seq // 2]}
    else:
        ds = SyntheticLMDataset(cfg.vocab, seq)

        def fn(step):
            idx = (np.arange(batch) + step * batch) % len(ds)
            return ds.batch(idx)
    return fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--noise", type=float, default=0.0)
    ap.add_argument("--strategy", default=None,
                    choices=[None, "naive", "multi", "crb", "ghost", "bk",
                             "auto"])
    ap.add_argument("--clip-mode", default="flat",
                    choices=["flat", "per_layer", "stale"],
                    help="clipping policy: flat (exact, default), "
                         "per_layer (per-layer budgets with sum C_l^2 = "
                         "C^2), or stale (lagged coefficients; fused "
                         "single-pass plan, 1 fwd + 1 bwd steady state)")
    ap.add_argument("--clip-budgets", default="uniform",
                    choices=["uniform", "auto"],
                    help="per_layer budget split: uniform, or auto "
                         "(tracked per-layer norm quantiles)")
    ap.add_argument("--microbatches", default=1,
                    type=lambda v: v if v == "auto" else int(v),
                    help="int, or 'auto' to derive from the plan's "
                         "peak-memory estimates")
    ap.add_argument("--mesh", default=None,
                    help="mesh spec, e.g. 'data:8': plan mesh-aware "
                         "(collective-bytes costs, topology-keyed "
                         "fingerprint) and run the step sharded over the "
                         "data axes")
    ap.add_argument("--explain", action="store_true",
                    help="print the per-layer execution plan and exit")
    ap.add_argument("--plan-json", default=None,
                    help="plan cache file: loaded if present (skips the "
                         "probe), written after planning otherwise")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--delta", type=float, default=1e-5)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override reduced d_model (e.g. ~100M scale)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model,
                          d_ff=(args.d_model * 4 if cfg.d_ff else 0),
                          head_dim=max(args.d_model // max(cfg.n_heads, 1),
                                       8))
    if args.layers:
        cfg = cfg.replace(n_layers=args.layers)
    model = build_model(cfg)
    # Non-flat clip modes need a per-group coefficient flow: respect an
    # explicit --strategy (DPConfig validates the combination), but only
    # override the model's configured default when it would be invalid.
    strategy = args.strategy or cfg.dp_strategy
    if args.clip_mode != "flat" and args.strategy is None \
            and strategy not in ("auto", "bk"):
        strategy = "auto"
    dpc = DPConfig(l2_clip=args.clip, noise_multiplier=args.noise,
                   strategy=strategy,
                   microbatches=args.microbatches, delta=args.delta,
                   clipping=ClipPolicy(mode=args.clip_mode,
                                       budgets=args.clip_budgets))
    batch_fn = make_batch_fn(cfg, args.batch, args.seq)
    n_data = 1 << 16
    acct = PrivacyAccountant(sampling_rate=args.batch / n_data,
                             noise_multiplier=args.noise)
    chaos = ChaosMonkey(fail_at_steps=args.fail_at)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.plan_json and os.path.exists(args.plan_json):
        n = costmodel.load_plan_store(args.plan_json)
        print(f"[plan] loaded {n} plan(s) from {args.plan_json}")

    # Plan once: the engine is the step.  Restarted segments re-enter here
    # with the plan cache warm, so only the first segment ever probes.
    # params0 doubles as every segment's (deterministic) starting point.
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh_from_spec
        mesh = make_mesh_from_spec(args.mesh)
        d = costmodel.mesh_data_size(costmodel.mesh_axes(mesh))
        if args.batch % d:
            raise SystemExit(f"--batch {args.batch} not divisible by the "
                             f"mesh's data-parallel degree {d}")
        print(f"[mesh] {costmodel.format_mesh(costmodel.mesh_axes(mesh))} "
              f"over {len(jax.devices())} devices")
    params0, _ = model.init(jax.random.PRNGKey(0))
    engine = PrivacyEngine(
        model.apply, params0, batch_fn(0), dp=dpc, optimizer="adamw",
        lr=lambda step: cosine_schedule(step, warmup=10, total=args.steps,
                                        peak=args.lr),
        weight_decay=0.01, accountant=acct, mesh=mesh)
    # Fixed strategies bypass the planner; don't pay an advisory probe for
    # them unless the user asks.
    if args.explain or dpc.strategy == "auto":
        print(engine.explain())
    if args.explain:
        return []
    if args.plan_json and not os.path.exists(args.plan_json):
        engine.save_plan(args.plan_json)
        print(f"[plan] wrote {args.plan_json}")

    # One monitor for the whole run: stragglers survive restarts instead of
    # being read off a fresh (empty) StepMonitor at the end.
    mon = StepMonitor()

    def segment(restart_count):
        params = params0
        opt = adamw_init(params)
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            (params, opt), start = ckpt.restore((params, opt))
            start += 1
            print(f"[restore] resuming from step {start}")
        losses = []
        for step in range(start, args.steps):
            chaos.maybe_fail(step)
            mon.start()
            batch = jax.tree.map(jnp.asarray, batch_fn(step))
            key = jax.random.PRNGKey(1000 + step)
            params, opt, loss, aux = engine.private_step(
                params, opt, batch, jax.random.key_data(key))
            dt = mon.stop(step)
            losses.append(float(loss))
            if step % 10 == 0 or step == args.steps - 1:
                # Under stale clipping the honest "what did this step
                # apply" metric is the lagged one; under per_layer the
                # scalar is the mean over (layer, example) pairs of the
                # per-layer fractions also present in aux.
                if "clip_fraction_lagged" in aux:
                    clip_msg = (f"clip_frac(lagged) "
                                f"{float(aux['clip_fraction_lagged']):.2f}")
                else:
                    clip_msg = f"clip_frac {float(aux['clip_fraction']):.2f}"
                print(f"step {step:4d} loss {float(loss):.4f} "
                      f"{clip_msg} {dt*1e3:.0f}ms"
                      + (f" [{engine.report()}]" if args.noise else ""))
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step, (params, opt))
        if ckpt:
            ckpt.wait()
            ckpt.save(args.steps - 1, (params, opt))
        return losses

    losses, restarts = run_with_restarts(segment, max_restarts=5)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}), "
          f"restarts={restarts}, stragglers={len(mon.stragglers)}")
    if args.noise:
        print(engine.report())
    return losses


if __name__ == "__main__":
    main()
