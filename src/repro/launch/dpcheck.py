"""Static DP-invariant checker: trace the private step, prove it, exit.

``dpcheck`` is the CI face of :mod:`repro.analysis`.  For every
``arch x clip-mode x mesh`` lane it builds the model reduced, constructs
a :class:`~repro.core.PrivacyEngine`, and calls ``engine.verify()`` —
which traces the jitted private step to a jaxpr and abstractly
interprets it, *without executing a single step*:

  * per-example taint: every released gradient is clipped before any
    cross-example reduction (all clip modes, incl. the fused gram path);
  * noise discipline: one fresh f32 Gaussian per released leaf at
    ``sigma = noise_multiplier * l2_clip``, keys chained to the step key;
  * sharding safety: batch data-sharded, params/opt state/key/clip state
    and outputs replicated, clip decisions global, noise drawn once;
  * plan/graph consistency: the ExecPlan's realizations actually appear
    in the traced graph, the STATS census matches, the fingerprint
    (which now folds in a hash of the model/core source) is stable.

Exit status is 1 if any lane reports an error (or, with
``--fail-on-warn``, a warning), so a CI job wired to this module is a
hard gate: a refactor that silently drops the clip, reuses a noise key,
or de-realizes a planned kernel fails the build before it can train.

    PYTHONPATH=src python -m repro.launch.dpcheck \
        --archs alexnet vgg16 llama3.2-1b \
        --clip-modes flat per_layer stale --mesh none data:8
"""
from __future__ import annotations

import argparse
import sys

if __name__ == "__main__":
    # ``--mesh data:8`` lanes need the devices to exist before the jax
    # backend initializes (same contract as launch.train).
    from repro.launch.mesh import force_host_device_count_for
    force_host_device_count_for(sys.argv)

import jax

from repro.configs import get_config
from repro.core import ClipPolicy, DPConfig, PrivacyEngine, costmodel
from repro.launch.train import make_batch_fn
from repro.models.registry import build_model


def _build_engine(arch: str, clip_mode: str, mesh_spec, *,
                  batch: int, seq: int, noise: float, clip: float,
                  run_seed: int, strategy: str,
                  dp_attn: bool = False) -> PrivacyEngine:
    cfg = get_config(arch).reduced()
    if dp_attn:
        cfg = cfg.replace(dp_attn=True)
    model = build_model(cfg)
    if clip_mode != "flat" and strategy not in ("auto", "bk"):
        strategy = "auto"
    dpc = DPConfig(l2_clip=clip, noise_multiplier=noise, strategy=strategy,
                   clipping=ClipPolicy(mode=clip_mode))
    mesh = None
    if mesh_spec and mesh_spec != "none":
        from repro.launch.mesh import make_mesh_from_spec
        mesh = make_mesh_from_spec(mesh_spec)
        d = costmodel.mesh_data_size(costmodel.mesh_axes(mesh))
        if batch % d:
            raise SystemExit(f"--batch {batch} not divisible by the "
                             f"mesh's data degree {d}")
    batch_fn = make_batch_fn(cfg, batch, seq)
    params0, axes0 = model.init(jax.random.PRNGKey(0))
    return PrivacyEngine(model.apply, params0, batch_fn(0), dp=dpc,
                         optimizer="adamw", lr=1e-3, weight_decay=0.01,
                         mesh=mesh, param_axes=axes0, run_seed=run_seed,
                         calibration="analytic")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="statically verify DP invariants of the private step")
    ap.add_argument("--archs", nargs="+", default=["alexnet"])
    ap.add_argument("--clip-modes", nargs="+", default=["flat"],
                    choices=["flat", "per_layer", "stale"])
    ap.add_argument("--mesh", nargs="+", default=["none"],
                    help="mesh specs per lane; 'none' = single device")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--noise", type=float, default=0.8)
    ap.add_argument("--run-seed", type=int, default=0)
    ap.add_argument("--dp-attn", action="store_true",
                    help="enable the block-level attention realization "
                         "(dp_attn=True) so attention lanes exercise the "
                         "attn ghost-norm path")
    ap.add_argument("--strategy", default="auto",
                    help="per-example gradient strategy; 'auto' (default) "
                         "exercises the planner so the plan/graph "
                         "consistency pass has a plan to check")
    ap.add_argument("--coll-bytes-warn", type=int, default=None,
                    help="per-device collective-bytes warning threshold")
    ap.add_argument("--fail-on-warn", action="store_true",
                    help="treat warnings as failures too")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every finding, not just failures")
    args = ap.parse_args(argv)

    lanes = [(a, m, s) for a in args.archs for m in args.clip_modes
             for s in args.mesh]
    failed = []
    for arch, mode, spec in lanes:
        name = f"{arch} clip={mode} mesh={spec}"
        if args.dp_attn:
            name += " dp_attn"
        # Lanes re-plan per topology; don't let a cached single-device
        # plan leak into a mesh lane or vice versa.
        costmodel.clear_plan_cache()
        engine = _build_engine(arch, mode, spec, batch=args.batch,
                               seq=args.seq, noise=args.noise,
                               clip=args.clip, run_seed=args.run_seed,
                               strategy=args.strategy,
                               dp_attn=args.dp_attn)
        report = engine.verify(coll_bytes_warn=args.coll_bytes_warn)
        bad = bool(report.errors) or (args.fail_on_warn
                                      and bool(report.warnings))
        status = "FAIL" if bad else "PASS"
        extra = ""
        if report.warnings and not bad:
            extra = f"  ({len(report.warnings)} warning(s))"
        print(f"[dpcheck] {status}  {name}{extra}")
        shown = report.findings if args.verbose else (
            report.errors + report.warnings if bad else report.warnings)
        for f in shown:
            print(f"    {f.severity:7s} {f.code:28s} {f.message}")
        if bad:
            failed.append(name)
    print(f"[dpcheck] {len(lanes) - len(failed)}/{len(lanes)} lanes clean")
    if failed:
        for name in failed:
            print(f"[dpcheck]   failed: {name}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
