"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

Causal GQA attention without materializing the (T, S) score matrix in HBM.
Forward grid (B, H, T/bq, S/bk); the last grid dim is sequential and
carries the online-softmax state (row max m, row sum l, output
accumulator) in VMEM scratch.  GQA is handled in the k/v index maps
(h -> h // rep) so the shared KV heads are never physically repeated.

The forward also emits the log-sum-exp rows (L = m + log l), which makes
the backward a pure recomputation pass: ``jax.custom_vjp`` wires in two
blockwise kernels — dq on the forward grid, dk/dv on a (B, Hkv, S/bk,
rep*T/bq) grid whose sequential last dim accumulates over both query
blocks and the GQA head group — so per-example attention gradients never
materialize either.  That differentiability is what lets the DP path run
ghost norms *through* an attention block (the tap cotangents of the
wq/wk/wv/wo projections come out of one ordinary backward).

Used by the serving prefill path (32k-sequence attention is memory-bound;
the score tensor alone would be T²·H·4 bytes) and by training whenever
``models.attention.attend`` dispatches ``impl="flash"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu is only importable where TPU lowering exists; interpret-safe
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG = -1e30


class FlashShapeError(ValueError):
    """Sequence/block geometry ``flash_attention`` cannot run (named, so
    32k-prefill callers get a message instead of a stripped ``assert``)."""


def _causal_mask(s, i, j, bq, bk):
    qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    return jnp.where(kj <= qi, s, NEG)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                  acc_scr, *, scale: float, bq: int, bk: int, causal: bool):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                       # (bq, hd)
    k = k_ref[0, 0]                       # (bk, hd)
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        s = _causal_mask(s, i, j, bq, bk)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + \
        jnp.dot(p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new
    l_scr[:, 0] = l_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _finish():
        l = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[:, 0] + jnp.log(l)).astype(lse_ref.dtype)


def _flash_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dq_ref, dq_scr, *, scale: float, bq: int, bk: int,
                     causal: bool):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    do = do_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        s = _causal_mask(s, i, j, bq, bk)
    p = jnp.exp(s - lse_ref[0, 0][:, None])
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0, 0][:, None]) * scale
    dq_scr[...] += jnp.dot(ds.astype(k.dtype), k,
                           preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(3) - 1)
    def _finish():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dk_ref, dv_ref, dk_scr, dv_scr, *, scale: float,
                      bq: int, bk: int, causal: bool, n_tq: int):
    jk, t = pl.program_id(2), pl.program_id(3)
    i = t % n_tq                          # query-block index within a head

    @pl.when(t == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    do = do_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        s = _causal_mask(s, i, jk, bq, bk)
    p = jnp.exp(s - lse_ref[0, 0][:, None])
    dv_scr[...] += jnp.dot(p.astype(do.dtype).T, do,
                           preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0, 0][:, None]) * scale
    dk_scr[...] += jnp.dot(ds.astype(q.dtype).T, q,
                           preferred_element_type=jnp.float32)

    @pl.when(t == pl.num_programs(3) - 1)
    def _finish():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _fwd_call(q, k, v, causal, bq, bk, interpret):
    """(o, lse) on (B,T,H,hd)/(B,Hkv,S,hd) inputs; lse is (B,H,T) f32."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    rep = H // k.shape[2]
    scale = hd ** -0.5
    qt = jnp.moveaxis(q, 2, 1)            # (B,H,T,hd)
    kt = jnp.moveaxis(k, 2, 1)            # (B,Hkv,S,hd)
    vt = jnp.moveaxis(v, 2, 1)

    if _VMEM is not None:
        scratch = [_VMEM((bq, 1), jnp.float32), _VMEM((bq, 1), jnp.float32),
                   _VMEM((bq, hd), jnp.float32)]
    else:  # pragma: no cover
        scratch = [pl.MemorySpace.ANY] * 3

    out, lse = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                          causal=causal),
        grid=(B, H, T // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
                   jax.ShapeDtypeStruct((B, H, T), jnp.float32)],
        scratch_shapes=scratch,
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2), lse


def _bwd_call(q, k, v, o, lse, do, causal, bq, bk, interpret):
    """(dq, dk, dv) by blockwise recomputation from the saved lse rows."""
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    n_tq = T // bq
    scale = hd ** -0.5
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    dot = jnp.moveaxis(do, 2, 1)          # (B,H,T,hd)
    # D_i = rowsum(dO ∘ O): the softmax-jacobian correction, cheap in XLA.
    delta = jnp.sum(dot.astype(jnp.float32)
                    * jnp.moveaxis(o, 2, 1).astype(jnp.float32), axis=-1)

    if _VMEM is not None:
        dq_scr = [_VMEM((bq, hd), jnp.float32)]
        dkv_scr = [_VMEM((bk, hd), jnp.float32),
                   _VMEM((bk, hd), jnp.float32)]
    else:  # pragma: no cover
        dq_scr = [pl.MemorySpace.ANY]
        dkv_scr = [pl.MemorySpace.ANY] * 2

    q_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, hd),
                           lambda b, h, i, j, rep=rep: (b, h // rep, j, 0))
    row_spec = pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i))
    dq = pl.pallas_call(
        functools.partial(_flash_dq_kernel, scale=scale, bq=bq, bk=bk,
                          causal=causal),
        grid=(B, H, T // bq, S // bk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        scratch_shapes=dq_scr,
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    # dk/dv: sequential last dim walks (head-group r, query block i) pairs
    # so each (b, hkv, key-block) accumulates over every query that saw it.
    def _qi(b, hkv, jk, t, rep=rep, n_tq=n_tq):
        return (b, hkv * rep + t // n_tq, t % n_tq, 0)

    def _rows(b, hkv, jk, t, rep=rep, n_tq=n_tq):
        return (b, hkv * rep + t // n_tq, t % n_tq)

    dk, dv = pl.pallas_call(
        functools.partial(_flash_dkv_kernel, scale=scale, bq=bq, bk=bk,
                          causal=causal, n_tq=n_tq),
        grid=(B, Hkv, S // bk, rep * n_tq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), _qi),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, jk, t: (b, h, jk, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, jk, t: (b, h, jk, 0)),
            pl.BlockSpec((1, 1, bq, hd), _qi),
            pl.BlockSpec((1, 1, bq), _rows),
            pl.BlockSpec((1, 1, bq), _rows),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, jk, t: (b, h, jk, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, jk, t: (b, h, jk, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, Hkv, S, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, Hkv, S, hd), v.dtype)],
        scratch_shapes=dkv_scr,
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)
    return (jnp.moveaxis(dq, 1, 2), jnp.moveaxis(dk, 1, 2),
            jnp.moveaxis(dv, 1, 2))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, bq, bk, interpret):
    o, _ = _fwd_call(q, k, v, causal, bq, bk, interpret)
    return o


def _flash_fwd_rule(q, k, v, causal, bq, bk, interpret):
    o, lse = _fwd_call(q, k, v, causal, bq, bk, interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, bq, bk, interpret, res, do):
    q, k, v, o, lse = res
    return _bwd_call(q, k, v, o, lse, do, causal, bq, bk, interpret)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: bool | None = None):
    """q (B,T,H,hd); k,v (B,S,Hkv,hd) with H % Hkv == 0 -> (B,T,H,hd).

    Differentiable (``jax.custom_vjp`` over the blockwise backward).
    ``interpret=None`` derives the Pallas interpret flag from the backend
    (compiled on TPU, interpreted elsewhere).  Query lengths that don't
    divide ``bq`` are zero-padded and sliced back (padded rows are dead:
    each query row is independent); key lengths that don't divide ``bk``
    raise :class:`FlashShapeError` — padding keys would corrupt every
    real row's softmax normalizer.
    """
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    if Hkv == 0 or H % Hkv:
        raise FlashShapeError(
            f"flash_attention: {H} query heads are not a multiple of "
            f"{Hkv} kv heads")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bq, bk = min(bq, T), min(bk, S)
    if S % bk:
        raise FlashShapeError(
            f"flash_attention: key length S={S} does not divide into key "
            f"blocks of bk={bk}; pass a bk dividing S (zero-padding keys "
            f"would corrupt the softmax normalizer)")
    pad = -T % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = _flash(q, k, v, causal, bq, bk, interpret)
    return out[:, :T] if pad else out
