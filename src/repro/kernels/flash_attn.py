"""Pallas TPU kernel: blockwise online-softmax (flash) attention, forward.

Causal GQA attention without materializing the (T, S) score matrix in HBM.
Grid (B, H, T/bq, S/bk); the last grid dim is sequential and carries the
online-softmax state (row max m, row sum l, output accumulator) in VMEM
scratch.  GQA is handled in the k/v index maps (h -> h // rep) so the
shared KV heads are never physically repeated.

Used by the serving prefill path (32k-sequence attention is memory-bound;
the score tensor alone would be T²·H·4 bytes).  Training uses the XLA
chunked reference (attention backward via the kernel is future work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu is only importable where TPU lowering exists; interpret-safe
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, bq: int, bk: int, causal: bool):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                       # (bq, hd)
    k = k_ref[0, 0]                       # (bk, hd)
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kj <= qi, s, NEG)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + \
        jnp.dot(p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new
    l_scr[:, 0] = l_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: bool = True):
    """q (B,T,H,hd); k,v (B,S,Hkv,hd) with H % Hkv == 0 -> (B,T,H,hd)."""
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    bq, bk = min(bq, T), min(bk, S)
    assert T % bq == 0 and S % bk == 0, (T, bq, S, bk)
    scale = hd ** -0.5
    qt = jnp.moveaxis(q, 2, 1)            # (B,H,T,hd)
    kt = jnp.moveaxis(k, 2, 1)            # (B,Hkv,S,hd)
    vt = jnp.moveaxis(v, 2, 1)

    if _VMEM is not None:
        scratch = [_VMEM((bq, 1), jnp.float32), _VMEM((bq, 1), jnp.float32),
                   _VMEM((bq, hd), jnp.float32)]
    else:  # pragma: no cover
        scratch = [pl.MemorySpace.ANY] * 3

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                          causal=causal),
        grid=(B, H, T // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
