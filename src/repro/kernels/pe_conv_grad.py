"""Pallas TPU kernel: per-example convolution weight gradients.

The paper's Algorithm 2 as a direct TPU kernel instead of a grouped-conv
lowering: for each example b (and output-channel tile),

    δh[b,d,c,k] = Σ_t x[b,c,t+k] · δy[b,d,t]          (1-D)
    δh[b,d,c,kh,kw] = Σ_{h,w} x[b,c,h+kh,w+kw] δy[b,d,h,w]   (2-D)

Each (b, d-tile) grid cell holds x (C, spatial) and a δy tile in VMEM and
issues K (or KH·KW) MXU matmuls of shape (bd, T')×(T', C) — the kernel
windows are static unrolls, so there is no gather.  Stride/dilation/padding
are handled by the wrapper in ops.py (pre-dilating δy / padding x), which
falls back to the XLA grouped-conv lowering for exotic configurations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel_1d(x_ref, dy_ref, o_ref, *, K: int, Tp: int):
    x = x_ref[0]            # (C, T)
    dy = dy_ref[0]          # (bd, Tp)
    for k in range(K):
        xs = jax.lax.dynamic_slice_in_dim(x, k, Tp, axis=1)   # static k
        o_ref[0, :, :, k] = jnp.dot(dy, xs.T,
                                    preferred_element_type=jnp.float32)


def _kernel_2d(x_ref, dy_ref, o_ref, *, KH: int, KW: int, Hp: int, Wp: int):
    x = x_ref[0]            # (C, H, W)
    dy = dy_ref[0]          # (bd, Hp, Wp)
    dyf = dy.reshape(dy.shape[0], Hp * Wp)
    for kh in range(KH):
        for kw in range(KW):
            xs = x[:, kh:kh + Hp, kw:kw + Wp].reshape(x.shape[0], Hp * Wp)
            o_ref[0, :, :, kh, kw] = jnp.dot(
                dyf, xs.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("K", "bd", "interpret"))
def pe_conv_grad_1d(x, dy, *, K: int, bd: int = 0, interpret: bool = True):
    """x (B,C,T), dy (B,D,T') -> (B,D,C,K); stride=dilation=1, groups=1."""
    B, C, T = x.shape
    _, D, Tp = dy.shape
    bd = bd or D
    assert D % bd == 0
    return pl.pallas_call(
        functools.partial(_kernel_1d, K=K, Tp=Tp),
        grid=(B, D // bd),
        in_specs=[
            pl.BlockSpec((1, C, T), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, bd, Tp), lambda b, d: (b, d, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd, C, K), lambda b, d: (b, d, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D, C, K), jnp.float32),
        interpret=interpret,
    )(x, dy)


@functools.partial(jax.jit, static_argnames=("KH", "KW", "bd", "interpret"))
def pe_conv_grad_2d(x, dy, *, KH: int, KW: int, bd: int = 0,
                    interpret: bool = True):
    """x (B,C,H,W), dy (B,D,H',W') -> (B,D,C,KH,KW)."""
    B, C, H, W = x.shape
    _, D, Hp, Wp = dy.shape
    bd = bd or D
    assert D % bd == 0
    return pl.pallas_call(
        functools.partial(_kernel_2d, KH=KH, KW=KW, Hp=Hp, Wp=Wp),
        grid=(B, D // bd),
        in_specs=[
            pl.BlockSpec((1, C, H, W), lambda b, d: (b, 0, 0, 0)),
            pl.BlockSpec((1, bd, Hp, Wp), lambda b, d: (b, d, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd, C, KH, KW),
                               lambda b, d: (b, d, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D, C, KH, KW), jnp.float32),
        interpret=interpret,
    )(x, dy)
