"""Pallas TPU kernel: per-example ghost-norm Gram reduction.

Computes, per example b,

    out[b] = Σ_{t,t'} (x_{b,t}·x_{b,t'}) (δy_{b,t}·δy_{b,t'})   [+ bias term]

i.e. ‖δy_bᵀ x_b‖²_F without materializing either the per-example gradient
(T·Din·Dout) or the full (T,T) Gram matrices in HBM.  XLA realizes the same
contraction as two (B,T,T) batched matmuls with an HBM round-trip between
them; here the (bt × bt) Gram tiles live only in VMEM and feed the MXU
twice per tile pair.

Grid: (B, T/bt, T/bt); the output block (1,) is revisited across the two
inner (sequential) grid dims and accumulated in place.

A token-mask variant (for embedding-gather norms) multiplies the δy-Gram
tile by [ids_t == ids_{t'}] instead of an x-Gram.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BT = 256


def _gram_kernel(x_i, x_j, y_i, y_j, o_ref, *, has_bias: bool):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    gx = jnp.dot(x_i[0], x_j[0].T, preferred_element_type=jnp.float32)
    gy = jnp.dot(y_i[0], y_j[0].T, preferred_element_type=jnp.float32)
    acc = jnp.sum(gx * gy)
    if has_bias:
        acc = acc + jnp.sum(gy)
    o_ref[0] += acc


def _gram_tokmask_kernel(ids_i, ids_j, y_i, y_j, o_ref):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    gy = jnp.dot(y_i[0], y_j[0].T, preferred_element_type=jnp.float32)
    mask = (ids_i[0][:, None] == ids_j[0][None, :])
    o_ref[0] += jnp.sum(jnp.where(mask, gy, 0.0))


def _pad_t(a, bt):
    T = a.shape[1]
    pad = (-T) % bt
    if pad:
        cfg = [(0, 0)] * a.ndim
        cfg[1] = (0, pad)
        a = jnp.pad(a, cfg)
    return a


@functools.partial(jax.jit,
                   static_argnames=("has_bias", "bt", "interpret"))
def gram_norm(x, dy, *, has_bias: bool = False, bt: int = DEFAULT_BT,
              interpret: bool = True):
    """x (B,T,Din), dy (B,T,Dout) -> (B,) fp32 squared per-example norms."""
    B, T, Di = x.shape
    Do = dy.shape[-1]
    bt = min(bt, max(8, 1 << (T - 1).bit_length()))
    x, dy = _pad_t(x, bt), _pad_t(dy, bt)
    Tp = x.shape[1]
    grid = (B, Tp // bt, Tp // bt)
    return pl.pallas_call(
        functools.partial(_gram_kernel, has_bias=has_bias),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, Di), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bt, Di), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bt, Do), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bt, Do), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, i, j: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(x, x, dy, dy)


def _gram_fused_kernel(x_i, x_j, y_i, y_j, w_ref, n_ref, c_ref, cb_ref, *,
                       has_bias: bool):
    b, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((b == 0) & (i == 0) & (j == 0))
    def _init_contrib():
        c_ref[...] = jnp.zeros_like(c_ref)
        cb_ref[...] = jnp.zeros_like(cb_ref)

    @pl.when((i == 0) & (j == 0))
    def _init_norm():
        n_ref[...] = jnp.zeros_like(n_ref)

    gx = jnp.dot(x_i[0], x_j[0].T, preferred_element_type=jnp.float32)
    gy = jnp.dot(y_i[0], y_j[0].T, preferred_element_type=jnp.float32)
    acc = jnp.sum(gx * gy)
    if has_bias:
        acc = acc + jnp.sum(gy)
    n_ref[0] += acc

    # The contribution Σ_b w_b x_bᵀ δy_b needs each row tile once: fold it
    # into the j == 0 visit, where x_i / y_i are already VMEM-resident.
    @pl.when(j == 0)
    def _contrib():
        w = w_ref[0]
        c_ref[...] += w * jnp.dot(x_i[0].T, y_i[0],
                                  preferred_element_type=jnp.float32)
        if has_bias:
            cb_ref[...] += w * jnp.sum(y_i[0], axis=0)


@functools.partial(jax.jit, static_argnames=("has_bias", "bt", "interpret"))
def gram_norm_fused(x, dy, w, *, has_bias: bool = False,
                    bt: int = DEFAULT_BT, interpret: bool = True):
    """Fused ghost-norm + weighted contribution in one VMEM-resident pass.

    x (B,T,Din), dy (B,T,Dout), w (B,) ->
        norms_sq (B,) fp32, contrib (Din,Dout) = Σ_b w_b·x_bᵀδy_b fp32,
        bias contrib (Dout,) = Σ_b w_b·Σ_t δy_bt (zeros unless has_bias).

    The norm's (bt×bt) Gram tiles and the contribution's row tiles share
    the same x/δy loads, so both outputs cost one HBM read of the inputs.
    Requires the weights to be known entering the pass — i.e. the
    book-keeping sum phase, stale-coefficient pipelines, or per-layer
    clipping (where a layer's coefficient depends only on its own norm).
    """
    B, T, Di = x.shape
    Do = dy.shape[-1]
    bt = min(bt, max(8, 1 << (T - 1).bit_length()))
    x, dy = _pad_t(x, bt), _pad_t(dy, bt)
    Tp = x.shape[1]
    grid = (B, Tp // bt, Tp // bt)
    return pl.pallas_call(
        functools.partial(_gram_fused_kernel, has_bias=has_bias),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, Di), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bt, Di), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bt, Do), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bt, Do), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1,), lambda b, i, j: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda b, i, j: (b,)),
            pl.BlockSpec((Di, Do), lambda b, i, j: (0, 0)),
            pl.BlockSpec((Do,), lambda b, i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((Di, Do), jnp.float32),
            jax.ShapeDtypeStruct((Do,), jnp.float32),
        ],
        interpret=interpret,
    )(x, x, dy, dy, w.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def gram_norm_tokmask(ids, dy, *, bt: int = DEFAULT_BT,
                      interpret: bool = True):
    """Embedding-gather ghost norm: out[b] = Σ_{t,t'} [id_t==id_t'] δy·δy."""
    B, T = ids.shape
    Do = dy.shape[-1]
    bt = min(bt, max(8, 1 << (T - 1).bit_length()))
    pad = (-T) % bt
    if pad:
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        dy = jnp.pad(dy, ((0, 0), (0, pad), (0, 0)))
    Tp = ids.shape[1]
    grid = (B, Tp // bt, Tp // bt)
    return pl.pallas_call(
        _gram_tokmask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bt), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, bt, Do), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bt, Do), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, i, j: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(ids, ids, dy, dy)
