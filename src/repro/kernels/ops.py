"""jit'd public wrappers + platform dispatch for the Pallas kernels.

On TPU the kernels lower natively; elsewhere (this CPU container, and the
multi-pod dry-run on the host platform) ``interpret=True`` executes the
kernel body for correctness, or the pure-jnp reference is used where the
interpreter would be too slow.  ``use_pallas()`` centralizes the decision.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attn as _fa
from repro.kernels import gram_norm as _gn
from repro.kernels import pe_conv_grad as _pc
from repro.kernels import ref as _ref

# Per-core VMEM the pe_conv_grad autotuner plans against: half of a TPU
# core's ~16 MiB, leaving room for the pipeline's double-buffering.
# The *analytic* default — vmem_budget() prefers the measured sweep
# winner from a registered calibration, and REPRO_VMEM_BUDGET overrides
# both.
VMEM_BUDGET = 8 << 20


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def vmem_budget() -> int:
    """The VMEM budget pe_conv_grad autotunes against, by precedence:
    ``REPRO_VMEM_BUDGET`` env override > the ``pe_conv_grad`` sweep
    winner in the registered calibration for the live hardware (see
    ``repro.calibrate.harness.sweep_pe_conv_vmem``) > the analytic
    :data:`VMEM_BUDGET`.  Read per call, outside the autotune cache, so
    registering a calibration mid-process takes effect."""
    env = os.environ.get("REPRO_VMEM_BUDGET")
    if env:
        return max(int(env), 1)
    try:
        from repro.calibrate import table as _ct
    except ImportError:       # pragma: no cover - calibrate always ships
        return VMEM_BUDGET
    for calib in _ct.registered():
        if calib.hardware != _ct.hardware_signature():
            continue
        budget = calib.kernels.get("pe_conv_grad", {}).get("vmem_budget")
        if budget:
            return int(budget)
    return VMEM_BUDGET


def gram_norm(x, dy, *, has_bias: bool = False, bt: int = 256):
    if on_tpu():
        return _gn.gram_norm(x, dy, has_bias=has_bias, bt=bt,
                             interpret=False)
    return _gn.gram_norm(x, dy, has_bias=has_bias, bt=bt, interpret=True)


def gram_norm_fused(x, dy, w, *, has_bias: bool = False, bt: int = 256):
    """Fused ghost-norm + weighted contribution (see gram_norm.py).

    On TPU the Pallas kernel keeps the Gram tiles and the contribution
    accumulator VMEM-resident (one HBM read of x/δy serves both
    outputs); elsewhere the pure-jnp reference realizes the same
    contract — the interpreter would dominate any wall-clock the fused
    path is supposed to save (kernel/ref agreement is pinned in
    tests/test_kernels.py)."""
    if on_tpu():
        return _gn.gram_norm_fused(x, dy, w, has_bias=has_bias, bt=bt,
                                   interpret=False)
    return _ref.gram_norm_fused_ref(x, dy, w, has_bias=has_bias)


def gram_norm_tokmask(ids, dy, *, bt: int = 256):
    return _gn.gram_norm_tokmask(ids, dy, bt=bt, interpret=not on_tpu())


@functools.lru_cache(maxsize=256)
def _autotune_bd(D: int, C: int, x_spatial: tuple, dy_spatial: tuple,
                 k_spatial: tuple, budget: int = VMEM_BUDGET) -> int:
    """Output-channel tile for the pe_conv_grad grid: the largest divisor
    of D whose VMEM working set — the full x block, the (bd, spatial') δy
    tile and the (bd, C, K) output tile — fits the budget."""
    import math
    x_elems = C * math.prod(x_spatial)
    per_row = math.prod(dy_spatial) + C * math.prod(k_spatial)
    divisors = sorted((d for d in range(1, D + 1) if D % d == 0),
                      reverse=True)
    for bd in divisors:
        if 4 * (x_elems + bd * per_row) <= budget:
            return bd
    return 1


def pick_bd(D: int, C: int, x_spatial: tuple, dy_spatial: tuple,
            k_spatial: tuple, budget: int = VMEM_BUDGET) -> int:
    """Analytic bd choice, overridable with REPRO_PE_CONV_BD (rounded down
    to a divisor of D so the kernel's tiling invariant holds).  The env
    var is read here, outside the cache, so mid-process sweeps work."""
    env = os.environ.get("REPRO_PE_CONV_BD")
    if env:
        want = max(1, min(int(env), D))
        return max(d for d in range(1, want + 1) if D % d == 0)
    return _autotune_bd(D, C, x_spatial, dy_spatial, k_spatial, budget)


def pe_conv_grad(x, dy, *, kernel_spatial, stride=1, dilation=1, padding=0,
                 groups: int = 1):
    """Pallas path for Algorithm 2, with bd-tiled grid autotuning.  Plain
    convs (stride=dilation=1, groups=1) hit the kernel; anything else
    falls back to the XLA grouped-conv lowering (still the paper's
    algorithm)."""
    from repro.models import convops

    def _as_tuple(v, n):
        return tuple(v) if isinstance(v, (tuple, list)) else (v,) * n

    rank = len(kernel_spatial)
    plain = (groups == 1 and _as_tuple(stride, rank) == (1,) * rank
             and _as_tuple(dilation, rank) == (1,) * rank)
    interp = not on_tpu()
    if plain and rank in (1, 2):
        p = _as_tuple(padding, rank)
        if any(p):
            cfg = [(0, 0), (0, 0)] + [(pi, pi) for pi in p]
            x = jnp.pad(x, cfg)
        bd = pick_bd(dy.shape[1], x.shape[1], tuple(x.shape[2:]),
                     tuple(dy.shape[2:]), tuple(kernel_spatial),
                     budget=vmem_budget())
        if rank == 1:
            return _pc.pe_conv_grad_1d(x, dy, K=kernel_spatial[0], bd=bd,
                                       interpret=interp)
        return _pc.pe_conv_grad_2d(x, dy, KH=kernel_spatial[0],
                                   KW=kernel_spatial[1], bd=bd,
                                   interpret=interp)
    return convops.pe_conv_grad(x, dy, kernel_spatial=kernel_spatial,
                                stride=stride, dilation=dilation,
                                padding=padding, groups=groups, impl="fgc")


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512):
    """Differentiable flash dispatch: the Pallas kernel (custom_vjp
    blockwise backward) on TPU, the interpreter for small CPU shapes,
    and the chunked-XLA reference beyond that — autodiff through the
    chunk loop keeps the backward's score working set one query chunk
    wide, matching the kernel's memory contract."""
    if on_tpu():
        return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                                   interpret=False)
    # CPU: the interpreter is correct but slow; keep it for small shapes,
    # use the chunked reference beyond that.
    if q.shape[1] * k.shape[1] <= 1 << 20:
        return _fa.flash_attention(q, k, v, causal=causal,
                                   bq=min(bq, q.shape[1]),
                                   bk=min(bk, k.shape[1]), interpret=True)
    return _ref.flash_attention_chunked_ref(q, k, v, causal=causal,
                                            chunk=bq)
