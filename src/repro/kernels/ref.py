"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gram_norm_ref(x, dy, *, has_bias: bool = False):
    """out[b] = ‖δy_bᵀ x_b‖²_F  (+ ‖Σ_t δy‖² if has_bias)."""
    g = jnp.einsum("bti,bto->bio", x.astype(jnp.float32),
                   dy.astype(jnp.float32))
    n = jnp.sum(g * g, axis=(1, 2))
    if has_bias:
        bg = jnp.sum(dy.astype(jnp.float32), axis=1)
        n = n + jnp.sum(bg * bg, axis=1)
    return n


def gram_norm_fused_ref(x, dy, w, *, has_bias: bool = False):
    """Fused ghost-norm + weighted contribution:
    (‖δy_bᵀx_b‖²_F [+ bias], Σ_b w_b·x_bᵀδy_b, Σ_b w_b·Σ_t δy_bt).

    Matches the kernel's cost shape: the norm via the T×T Gram identity
    (never materializing the (B, Din, Dout) per-example products — in
    the Gram regime that materialization costs orders of magnitude more
    FLOPs than the norm itself) and the contribution as one direct
    (B·T)-row contraction."""
    xf, gf = x.astype(jnp.float32), dy.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    sx = jnp.einsum("bti,bsi->bts", xf, xf)
    sy = jnp.einsum("bto,bso->bts", gf, gf)
    n = jnp.einsum("bts,bts->b", sx, sy)
    c = jnp.einsum("b,bti,bto->io", wf, xf, gf)
    cb = jnp.zeros((dy.shape[-1],), jnp.float32)
    if has_bias:
        n = n + jnp.sum(sy, axis=(1, 2))
        cb = jnp.einsum("b,bto->o", wf, gf)
    return n, c, cb


def gram_norm_tokmask_ref(ids, dy):
    dyf = dy.astype(jnp.float32)
    sy = jnp.einsum("btd,bsd->bts", dyf, dyf)
    m = (ids[:, :, None] == ids[:, None, :]).astype(jnp.float32)
    return jnp.einsum("bts,bts->b", m, sy)


def pe_conv_grad_1d_ref(x, dy, K: int):
    """Brute-force: δh[b,d,c,k] = Σ_t x[b,c,t+k] dy[b,d,t]."""
    B, C, T = x.shape
    _, D, Tp = dy.shape
    xs = jnp.stack([x[:, :, k:k + Tp] for k in range(K)], axis=-1)  # (B,C,Tp,K)
    return jnp.einsum("bctk,bdt->bdck", xs.astype(jnp.float32),
                      dy.astype(jnp.float32))


def pe_conv_grad_2d_ref(x, dy, KH: int, KW: int):
    B, C, H, W = x.shape
    _, D, Hp, Wp = dy.shape
    out = []
    for kh in range(KH):
        row = []
        for kw in range(KW):
            xs = x[:, :, kh:kh + Hp, kw:kw + Wp]
            row.append(jnp.einsum("bchw,bdhw->bdc", xs.astype(jnp.float32),
                                  dy.astype(jnp.float32)))
        out.append(jnp.stack(row, axis=-1))
    return jnp.stack(out, axis=-2)  # (B,D,C,KH,KW)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bthd,bshd->bhts", q, kr,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((T, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", p.astype(v.dtype), vr)


def flash_attention_chunked_ref(q, k, v, *, causal: bool = True,
                                chunk: int = 512):
    """Chunked-XLA flash oracle: the (T, S) score matrix exists one query
    chunk at a time, never whole, and autodiff through the chunk loop
    gives the same memory shape backward — the CPU/interpret dispatch
    target for long sequences where ``flash_attention_ref`` would
    materialize T²·H scores (and its backward twice that)."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    rep = H // k.shape[2]
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    chunk = min(chunk, T)
    pad = -T % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ks = jnp.arange(S)

    def one(args):
        qc, t0 = args                     # (B,chunk,H,hd), scalar start
        s = jnp.einsum("bthd,bshd->bhts", qc, kr,
                       preferred_element_type=jnp.float32) * hd ** -0.5
        if causal:
            qi = t0 + jnp.arange(chunk)
            s = jnp.where((ks[None, :] <= qi[:, None])[None, None],
                          s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhts,bshd->bthd", p.astype(v.dtype), vr)

    n = (T + pad) // chunk
    qs = q.reshape(B, n, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    out = jax.lax.map(one, (qs, jnp.arange(n) * chunk))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, T + pad, H, hd)
    return out[:, :T] if pad else out
