"""Trace a PrivacyEngine's private step and verify DP invariants.

:func:`verify_engine` is what ``engine.verify()`` and the ``dpcheck``
CLI call: it traces the engine's *unjitted* step closure to a
ClosedJaxpr with ``jax.make_jaxpr`` (no execution, no devices needed —
the mesh lane verifies the declared shardings, not a compiled
executable), flattens it (:mod:`repro.analysis.graph`), and runs four
passes:

  * taint      (:mod:`repro.analysis.taint`)      — clip before any
    batch reduction on every path to the released params/opt state;
  * noise      (:mod:`repro.analysis.noise`)      — one fresh f32
    Gaussian per released leaf at scale sigma·C, keys from the step key
    input, no reuse;
  * sharding   (:mod:`repro.analysis.shardcheck`) — mesh lanes: batch
    data-sharded, everything else (incl. the key and every output)
    replicated, clip decisions global, noise aggregate-level;
  * plan       (:mod:`repro.analysis.plancheck`)  — the ExecPlan's
    declared realizations actually executed (marker + STATS census),
    live fingerprint, collective-traffic warning.

Violations that only feed the *monitoring* outputs (the mean loss, clip
fractions) are filtered by a backward slice from the params/optimizer
outputs — ``mean(losses)`` legitimately averages over examples; what it
feeds is released as a float, not as the model update, and is outside
the clip→noise mechanism this verifier polices.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.analysis import graph as graphlib
from repro.analysis import noise as noiselib
from repro.analysis import plancheck, shardcheck
from repro.analysis import taint as taintlib
from repro.analysis.graph import Var
from repro.analysis.report import Finding, VerifyReport


def _spec(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(tuple(a.shape), a.dtype), tree)


def _opt_spec(engine, opt):
    if opt is not None:
        return _spec(opt)
    name = getattr(engine, "_optimizer_name", None)
    from repro.optim import adamw_init, sgdm_init
    table = {"adamw": adamw_init, "sgdm": sgdm_init}
    if name not in table:
        raise ValueError(
            "engine uses a custom optimizer callable; pass opt= (a live "
            "or abstract optimizer state) to verify()")
    return jax.eval_shape(table[name], engine._params_spec)


def _clip_state_spec(engine, B):
    clip = engine.dp.clipping
    if clip.mode == "stale":
        # Verify the steady state (the bootstrap step IS the flat
        # pipeline, covered by the flat lane).
        return {"prev_norms_sq": jax.ShapeDtypeStruct((B,), jnp.float32)}
    if clip.mode == "per_layer" and clip.budgets == "auto":
        return _spec(engine._clip_state())
    return {}


def _classify_outputs(graph, out_shape):
    """Vars feeding the released params/opt outputs (tuple slots 0, 1)."""
    leaves = jax.tree_util.tree_leaves_with_path(out_shape)
    sinks = []
    for (kp, _), v in zip(leaves, graph.outvars):
        slot = getattr(kp[0], "idx", None)
        if slot in (0, 1) and isinstance(v, Var):
            sinks.append(v)
    return sinks


def verify_engine(engine, *, opt=None,
                  coll_bytes_warn: Optional[float] = None) -> VerifyReport:
    """Statically verify one engine's private step.  Returns a
    :class:`~repro.analysis.report.VerifyReport`; never executes the
    step."""
    from repro.core import costmodel
    from repro.core.tapper import STATS

    findings: List[Finding] = []
    checked = {}
    mode = engine.dp.clipping.mode
    sigma_mult = engine.dp.noise_multiplier
    l2_clip = engine.dp.l2_clip
    B = jax.tree.leaves(engine._batch_spec)[0].shape[0]
    stale_steady = mode == "stale"

    # Planning (and any probes) happen before the STATS snapshot, so the
    # traced-step census below sees only the step's own phases.
    plan = engine._exec_plan()
    m = engine.microbatches()
    step = engine._step_fn()

    params_spec = engine._params_spec
    opt_spec = _opt_spec(engine, opt)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    cs_spec = _clip_state_spec(engine, B)

    before = {k: getattr(STATS, k)
              for k in ("forwards", "backwards", "probes", "fused")}
    closed, out_shape = jax.make_jaxpr(step, return_shape=True)(
        params_spec, opt_spec, engine._batch_spec, key_spec, cs_spec)
    stats_delta = {k: getattr(STATS, k) - v for k, v in before.items()}

    graph = graphlib.flatten(closed)

    # -- input var bookkeeping --------------------------------------------
    n_p = len(jax.tree.leaves(params_spec))
    n_o = len(jax.tree.leaves(opt_spec))
    batch_leaves = jax.tree.leaves(engine._batch_spec)
    n_b = len(batch_leaves)
    invars = graph.invars
    batch_vars = invars[n_p + n_o:n_p + n_o + n_b]
    key_vars = set(invars[n_p + n_o + n_b:n_p + n_o + n_b + 1])
    cs_vars = invars[n_p + n_o + n_b + 1:]

    init = {}
    for v, leaf in zip(batch_vars, batch_leaves):
        if leaf.shape and leaf.shape[0] == B:
            init[v] = taintlib.Taint(frozenset({0}))
    for v, (path, leaf) in zip(
            cs_vars, sorted(
                ((k, l) for k, l in (cs_spec or {}).items()))):
        if path == "prev_norms_sq":
            init[v] = taintlib.Taint(frozenset({0}))

    # -- taint pass --------------------------------------------------------
    res = taintlib.TaintPass(graph, B).run(init)
    sinks = _classify_outputs(graph, out_shape)
    released = graph.backward_slice(sinks)
    top_ids = {id(n) for n in graph.nodes}
    for viol in res.violations:
        if id(viol.node) in top_ids and not any(
                isinstance(ov, Var) and ov in released
                for ov in viol.node.outvars):
            continue  # feeds only the loss/monitoring outputs
        findings.append(Finding(
            "error", "unclipped_batch_reduction",
            viol.message + " on a path to the released model update",
            "taint"))
    if res.approx:
        uniq = sorted(set(res.approx))
        findings.append(Finding(
            "info", "taint_approximation",
            f"unmodeled primitives handled conservatively: {uniq[:8]}",
            "taint"))
    checked["taint"] = (
        f"all batch-axis reductions reaching the released update cross a "
        f"clip contraction ({len(graph.nodes)} top-level eqns, B={B})")

    # -- clip marker discipline -------------------------------------------
    clip_markers = [n for n, _ in graph.markers()
                    if n.params.get("kind") == "clip_coef"]
    if not clip_markers:
        findings.append(Finding(
            "error", "clip_missing",
            "no clip-coefficient marker in the traced step — the "
            "per-example clip was removed or replaced", "taint"))
    else:
        modes = {n.params.get("mode") for n in clip_markers}
        if mode not in modes and not (mode == "stale" and "flat" in modes):
            findings.append(Finding(
                "error", "clip_mode_mismatch",
                f"engine clips {mode!r} but the traced coefficients are "
                f"{sorted(modes)}", "taint"))
        for n in clip_markers:
            c = n.params.get("l2_clip")
            if c is not None and abs(float(c) - l2_clip) > 1e-9 * max(
                    l2_clip, 1.0):
                findings.append(Finding(
                    "error", "clip_bound_mismatch",
                    f"traced clip bound {c} != configured C={l2_clip}",
                    "taint"))
                break
    checked["clip"] = (f"{len(clip_markers)} clip-coefficient site(s), "
                       f"mode {mode!r}, C={l2_clip}")

    # -- noise pass --------------------------------------------------------
    findings.extend(noiselib.check_noise(
        graph, key_inputs=key_vars, n_param_leaves=n_p,
        noise_multiplier=sigma_mult, l2_clip=l2_clip))
    checked["noise"] = (
        f"one f32 Gaussian per released leaf ({n_p} leaves) at "
        f"sigma·C = {sigma_mult * l2_clip:g}, keys chained to the step "
        f"key input (fold_in(run_key, step) enforced host-side)"
        if sigma_mult > 0 else "noise_multiplier == 0: no draws expected")

    # -- sharding pass -----------------------------------------------------
    mesh_axes = engine._mesh_axes
    shardings = engine._step_shardings()
    findings.extend(shardcheck.check_sharding(
        graph, taints=res.taints, batch_size=B, mesh_axes=mesh_axes,
        data_size=costmodel.mesh_data_size(mesh_axes),
        in_shardings=shardings[0] if shardings else None,
        out_shardings=shardings[1] if shardings else None))
    model_axes = costmodel.mesh_model_axes(mesh_axes)
    params_partitioned = bool(shardings) and any(
        not shardcheck._is_replicated(s)
        for s in jax.tree.leaves(shardings[0][0]))
    layout = ("params/opt partitioned over "
              + "x".join(a for a, _ in model_axes)
              + ", key replicated, outputs data-replicated"
              if model_axes and params_partitioned
              else "params/opt/key/outputs replicated")
    checked["sharding"] = (
        f"batch data-sharded, {layout} on "
        f"{costmodel.format_mesh(mesh_axes)}; clip decisions global, "
        f"noise drawn once" if mesh_axes
        else "no mesh: single-device step")

    # -- plan pass ---------------------------------------------------------
    expected_fp = (engine._fingerprint()
                   if plan is not None and m == 1 else None)
    kw = {} if coll_bytes_warn is None else {
        "coll_bytes_warn": coll_bytes_warn}
    findings.extend(plancheck.check_plan(
        graph, plan=plan, clip_mode=mode, stale_steady=stale_steady,
        stats_delta=stats_delta, expected_fingerprint=expected_fp, **kw))
    checked["plan"] = (
        f"{len(plan.groups)} group realizations present in the graph, "
        f"STATS census {stats_delta}, fingerprint {plan.fingerprint or '-'}"
        if plan is not None
        else f"fixed strategy {engine.dp.strategy!r}: no plan to check")

    owner = getattr(engine.apply_fn, "__self__", None)
    model = (type(owner).__qualname__ if owner is not None
             else getattr(engine.apply_fn, "__qualname__", "<fn>"))
    target = (f"{model} "
              f"clip={mode} sigma={sigma_mult} B={B} "
              f"mesh={costmodel.format_mesh(mesh_axes)}"
              + (f" microbatches={m}" if m != 1 else ""))
    order = {"error": 0, "warning": 1, "info": 2}
    findings.sort(key=lambda f: order[f.severity])
    return VerifyReport(target=target, findings=findings, checked=checked)
