"""Noise-discipline pass: one fresh Gaussian per released aggregate.

Checks, over the flattened private-step graph:

  * **count** — with ``noise_multiplier > 0`` there is exactly one
    ``dp_tag[kind=noise]`` marker (and one ``erf_inv``, the structural
    core of the inverse-CDF Gaussian sampler) per released parameter
    leaf.  Zero draws = the noise was dropped; more = double noise (the
    variance, and hence the real ε, silently changes).
  * **scale** — each noise marker's recorded ``sigma`` equals
    ``noise_multiplier * l2_clip`` (the sensitivity-calibrated scale;
    the ``/denom`` normalization is applied uniformly to signal and
    noise afterwards, preserving the SNR the accountant assumes).
  * **precision** — noise is drawn in float32 *before* any cast to the
    parameter dtype, and the clip-decision inputs (clip coefficients,
    group norms) are float32: a bf16 norm loses mantissa exactly where
    the sensitivity proof needs exactness.
  * **key hygiene** — every ``random_bits`` consumption chains back
    through key plumbing (wrap/split/fold_in/slice) to the *step key
    input* of the jaxpr — never to a constant (a baked-in key makes the
    noise deterministic across runs) — and no two draws consume the
    same derived key (key reuse correlates noise across leaves, so the
    leaves no longer get independent Gaussians).

``fold_in(run_key, step)`` itself happens host-side (the step key is a
jaxpr *input*), so per-step key derivation is enforced at the engine
level (``PrivacyEngine._check_key``) and recorded here as checked.
"""
from __future__ import annotations

from typing import List, Optional, Set

import numpy as np

from repro.analysis.graph import FlatGraph, Literal, Var
from repro.analysis.report import Finding

# Primitives that only *route* key material without consuming it.
_KEY_PLUMBING = {
    "random_wrap", "random_unwrap", "random_split", "random_fold_in",
    "threefry2x32", "slice", "dynamic_slice", "squeeze", "reshape",
    "transpose", "convert_element_type", "copy", "dp_tag", "broadcast_in_dim",
    "concatenate", "rev", "bitcast_convert_type", "gather",
}

_F32 = {"float32"}


def _dtype_name(v) -> str:
    return str(getattr(getattr(v, "aval", None), "dtype", ""))


class _KeyTracer:
    """Walk a key operand back to its origin through plumbing prims."""

    def __init__(self, graph: FlatGraph, key_inputs: Set[Var]):
        self.graph = graph
        self.key_inputs = key_inputs

    def origin(self, v) -> str:
        """'input' | 'constant' | 'opaque:<prim>'."""
        seen = set()
        frontier = [v]
        saw_input = saw_const = False
        opaque: Optional[str] = None
        while frontier:
            cur = frontier.pop()
            if isinstance(cur, Literal):
                saw_const = True
                continue
            if cur in seen:
                continue
            seen.add(cur)
            if cur in self.key_inputs:
                saw_input = True
                continue
            node = self.graph.producer.get(cur)
            if node is None:
                # A jaxpr invar that is *not* the key input, or a const.
                if cur in self.graph.invars:
                    saw_input = True  # derived from some traced input
                else:
                    saw_const = True
                continue
            if node.prim in _KEY_PLUMBING:
                frontier.extend(node.invars)
            elif node.prim in ("iota", "add", "xor", "or", "and", "shift_left",
                               "shift_right_logical", "mul", "sub"):
                # threefry internals mix counters (iota) with key words.
                frontier.extend(iv for iv in node.invars
                                if not isinstance(iv, Literal))
            else:
                opaque = node.prim
        if saw_input:
            return "input"
        if opaque is not None:
            return f"opaque:{opaque}"
        return "constant"

    def derived_key_id(self, v):
        """Resolve through pure identity plumbing (wrap/unwrap/cast/tag)
        to the var that identifies *this particular derived key*: two
        draws resolving to the same var consume the same randomness."""
        while isinstance(v, Var):
            node = self.graph.producer.get(v)
            if node is None:
                return v
            if node.prim in ("random_wrap", "random_unwrap",
                            "convert_element_type", "copy", "dp_tag",
                            "reshape", "bitcast_convert_type"):
                v = node.invars[0]
                continue
            return v
        return v


def check_noise(graph: FlatGraph, *,
                key_inputs: Set[Var],
                n_param_leaves: int,
                noise_multiplier: float,
                l2_clip: float) -> List[Finding]:
    findings: List[Finding] = []
    where = "noise"

    markers = [(n, g) for n, g in graph.markers()
               if n.params.get("kind") == "noise"]
    n_erf_inv = graph.count_prim("erf_inv")

    if noise_multiplier <= 0.0:
        if markers:
            findings.append(Finding(
                "error", "noise_without_sigma",
                f"{len(markers)} noise marker(s) present but "
                f"noise_multiplier == {noise_multiplier}", where))
        return findings

    # -- count: one fresh Gaussian per released leaf ----------------------
    if len(markers) == 0:
        findings.append(Finding(
            "error", "noise_missing",
            "noise_multiplier > 0 but no Gaussian noise marker appears "
            "in the step graph — the release is un-noised", where))
    elif len(markers) < n_param_leaves:
        findings.append(Finding(
            "error", "noise_missing",
            f"only {len(markers)} noise draw(s) for {n_param_leaves} "
            f"released parameter leaves", where))
    elif len(markers) > n_param_leaves:
        findings.append(Finding(
            "error", "noise_duplicated",
            f"{len(markers)} noise draws for {n_param_leaves} released "
            f"parameter leaves — noise is added more than once, the "
            f"effective sigma differs from the accountant's", where))
    if n_erf_inv > n_param_leaves:
        findings.append(Finding(
            "error", "noise_duplicated",
            f"{n_erf_inv} Gaussian samplers (erf_inv) traced for "
            f"{n_param_leaves} released leaves", where))
    elif 0 < n_erf_inv < n_param_leaves and markers:
        findings.append(Finding(
            "warning", "noise_sampler_census",
            f"{n_erf_inv} erf_inv eqns vs {n_param_leaves} leaves — "
            f"sampler not recognized per-leaf (custom sampler?)", where))

    # -- scale: sigma == noise_multiplier * l2_clip -----------------------
    expect = float(noise_multiplier) * float(l2_clip)
    for node, _ in markers:
        sigma = float(node.params.get("sigma", float("nan")))
        if not np.isclose(sigma, expect, rtol=1e-6, atol=0.0):
            findings.append(Finding(
                "error", "noise_scale_mismatch",
                f"noise marker sigma={sigma} != noise_multiplier * "
                f"l2_clip = {expect}", where))
            break
        m = float(node.params.get("noise_multiplier", noise_multiplier))
        c = float(node.params.get("l2_clip", l2_clip))
        if not (np.isclose(m, noise_multiplier) and np.isclose(c, l2_clip)):
            findings.append(Finding(
                "error", "noise_scale_mismatch",
                f"noise marker recorded (noise_multiplier={m}, "
                f"l2_clip={c}) but the engine config says "
                f"({noise_multiplier}, {l2_clip})", where))
            break

    # -- precision: f32 draw, f32 clip decisions --------------------------
    for node, _ in markers:
        dt = _dtype_name(node.outvars[0])
        if dt and dt not in _F32:
            findings.append(Finding(
                "error", "noise_low_precision",
                f"noise drawn/scaled in {dt}, not float32 — the cast to "
                f"the param dtype must come *after* signal+noise", where))
            break
    for kind, code in (("clip_coef", "clip_coef_low_precision"),
                       ("group_norm", "norm_low_precision")):
        for node, _ in graph.markers():
            if node.params.get("kind") != kind:
                continue
            dt = _dtype_name(node.outvars[0])
            if dt and "float" in dt and dt not in _F32 \
                    and not dt.endswith("64"):
                findings.append(Finding(
                    "error", code,
                    f"{kind} computed in {dt}; clip decisions must be "
                    f"float32 (bf16 norms break the sensitivity bound)",
                    where))
                break

    # -- key hygiene ------------------------------------------------------
    tracer = _KeyTracer(graph, key_inputs)
    seen_ids = {}
    for node in graph.iter_nodes(recursive=False):
        if node.prim != "random_bits":
            continue
        key_op = node.invars[0]
        org = tracer.origin(key_op)
        if org == "constant":
            findings.append(Finding(
                "error", "key_constant",
                "a random_bits draw uses a constant key — noise would "
                "repeat identically across runs/steps", where))
        elif org.startswith("opaque"):
            findings.append(Finding(
                "warning", "key_opaque",
                f"key provenance passes through unmodeled {org}", where))
        kid = tracer.derived_key_id(key_op)
        if isinstance(kid, Var):
            if kid in seen_ids:
                findings.append(Finding(
                    "error", "key_reuse",
                    "two Gaussian draws consume the same derived key — "
                    "noise is correlated across leaves", where))
            seen_ids[kid] = node

    return findings
