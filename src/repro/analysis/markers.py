"""``dp_tag``: a zero-cost identity primitive carrying static metadata.

The static verifier (:mod:`repro.analysis`) reads the private step's
jaxpr.  Pattern-matching "the clip" or "the noise" out of raw primitive
soup (``min``/``div``/``erf_inv`` chains) would be fragile against any
refactor of :mod:`repro.core.strategies` — so the core pipeline *tags*
its semantically load-bearing values instead:

  * ``kind="clip_coef"``   — the per-example clip coefficients, at the
    point where they are computed (a mutant that replaces
    ``clip_coefficients`` wholesale loses the tag, which is itself a
    finding);
  * ``kind="group_norm"``  — a plan group's per-example squared norms,
    carrying the group key and the realized method;
  * ``kind="realization"`` — a kind-level norm realization after method
    resolution (the census the plan pass cross-checks);
  * ``kind="fused_impl"``  — the fused norm+contrib single-pass
    realizations (``gram_norm_fused``);
  * ``kind="noise"``       — each Gaussian noise term, carrying the
    structural scale ``sigma = noise_multiplier * l2_clip``.

``tag(x, **params)`` is the identity on ``x`` — it lowers to a no-op,
is linear under AD (cotangents pass through), and vmaps trivially — so
tagging costs nothing at runtime and survives ``jit``/``grad``/``vmap``
into the traced graph, where the analyzer finds it as a ``dp_tag`` eqn
with the params attached.  Only hashable static values (str/int/float/
bool) may be passed as params.
"""
from __future__ import annotations

from typing import Any

from jax.interpreters import ad, batching, mlir

try:  # jax >= 0.4.16
    from jax.extend.core import Primitive
except ImportError:  # pragma: no cover - older jax
    from jax.core import Primitive  # type: ignore[attr-defined, no-redef]

MARKER_PRIMITIVE = "dp_tag"

dp_tag_p = Primitive(MARKER_PRIMITIVE)
dp_tag_p.def_impl(lambda x, **params: x)
dp_tag_p.def_abstract_eval(lambda x, **params: x)
mlir.register_lowering(dp_tag_p, lambda ctx, x, **params: [x])
# Identity is linear: JVP passes tangents through, transpose passes
# cotangents through — a tagged value inside a differentiated region
# does not break AD (and the tag survives into the backward graph).
ad.deflinear2(dp_tag_p, lambda ct, _, **params: [ct])
batching.primitive_batchers[dp_tag_p] = \
    lambda args, dims, **params: (dp_tag_p.bind(args[0], **params), dims[0])

_ALLOWED = (str, int, float, bool)


def tag(x, **params: Any):
    """Identity on ``x``, recording ``params`` in the traced graph.

    ``params`` must include ``kind=`` and contain only static hashable
    scalars; they surface verbatim as the ``dp_tag`` eqn's params.
    """
    if "kind" not in params:
        raise ValueError("dp_tag requires a kind= param")
    for k, v in params.items():
        if not isinstance(v, _ALLOWED):
            raise TypeError(
                f"dp_tag param {k}={v!r} is not a static scalar "
                f"(str/int/float/bool)")
    return dp_tag_p.bind(x, **params)


def is_marker(eqn) -> bool:
    """True if a jaxpr eqn is a ``dp_tag`` marker."""
    return eqn.primitive.name == MARKER_PRIMITIVE
