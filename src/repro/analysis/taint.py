"""Per-example taint tracking over the flattened private-step graph.

The lattice value for each variable records (a) which of its axes carry
the *example* dimension (``batch``), (b) whether the value has been
scaled by a per-example clip coefficient (``clipped`` — structurally:
the ``dp_tag[kind=clip_coef]`` marker entered multiplicatively on its
history), and (c) whether the value *is* coefficient-derived
(``weight``).

The invariant proved: on every path from per-example quantities to the
released parameter/optimizer outputs, a clip contraction happens
*before* any batch-axis reduction.  Concretely, any reduction over a
batch-tainted axis (``reduce_sum``, a contracting ``dot_general``, a
conv weight-gradient contraction, a ``scatter-add``) whose operands are
neither clipped nor coefficient-derived is recorded as a violation;
violations whose results reach the params/opt outputs are errors
(reductions feeding only the loss/aux monitoring outputs — the mean
loss, clip fractions — are the expected exemptions).

This is a structural lattice walk, not a sensitivity calculus: it
proves the *shape* of the pipeline (clip-then-reduce, exactly the class
of bug Lee & Kifer 2020 catalogue), with conservative fallbacks for
primitives it does not model (flagged as approximations).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Tuple

import numpy as np

from repro.analysis.graph import FlatGraph, Literal, Node, Var

EMPTY: FrozenSet[int] = frozenset()


@dataclasses.dataclass(frozen=True)
class Taint:
    batch: FrozenSet[int] = EMPTY   # axes carrying the example dim
    clipped: bool = False           # clip coefficient entered the chain
    weight: bool = False            # value is coefficient-derived

    @property
    def per_example(self) -> bool:
        return bool(self.batch)

    def shift(self, delta: int) -> "Taint":
        return dataclasses.replace(
            self, batch=frozenset(a + delta for a in self.batch
                                  if a + delta >= 0))


NONE = Taint()


@dataclasses.dataclass
class Violation:
    node: Node
    message: str


@dataclasses.dataclass
class TaintResult:
    taints: Dict[Var, Taint]
    violations: List[Violation]
    approx: List[str]


# Elementwise / same-shape primitives where taint unions across operands
# (the generic same-shape rule below covers most; these are ones whose
# tainted operands may be scalars/broadcast-shaped too).
_MUL_LIKE = {"mul", "div"}
_ADD_LIKE = {"add", "sub", "add_any"}
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "reduce_and", "reduce_or", "reduce_xor", "argmax", "argmin"}
_SHAPE_PASS = {"copy", "convert_element_type", "stop_gradient",
               "reduce_precision", "dp_tag", "neg", "abs", "sign", "sqrt",
               "rsqrt", "exp", "log", "tanh", "logistic", "erf", "erf_inv",
               "floor", "ceil", "round", "is_finite", "not", "real", "imag",
               "integer_pow", "exp2", "log1p", "expm1", "cbrt", "square",
               "sin", "cos", "tan", "sinh", "cosh", "asin", "acos", "atan",
               "asinh", "acosh", "atanh", "erfc", "logistic", "rev",
               "optimization_barrier"}


def _get(taints, v) -> Taint:
    if isinstance(v, Literal):
        return NONE
    return taints.get(v, NONE)


def _shape(v) -> Tuple[int, ...]:
    return tuple(getattr(v.aval, "shape", ()) or ())


class TaintPass:
    def __init__(self, graph: FlatGraph, batch_size: int):
        self.graph = graph
        self.B = batch_size
        self.violations: List[Violation] = []
        self.approx: List[str] = []

    # -- entry -------------------------------------------------------------

    def run(self, init: Dict[Var, Taint]) -> TaintResult:
        taints = dict(init)
        for node in self.graph.nodes:
            self._step(node, taints)
        return TaintResult(taints, self.violations, self.approx)

    # -- helpers -----------------------------------------------------------

    def _violate(self, node: Node, msg: str):
        self.violations.append(Violation(node, msg))

    def _covered(self, ins: List[Taint]) -> bool:
        """A batch reduction is structurally covered by the clip when any
        operand is clipped or coefficient-derived."""
        return any(t.clipped or t.weight for t in ins)

    def _reduce_event(self, node: Node, ins: List[Taint], what: str):
        if not self._covered(ins):
            self._violate(node,
                          f"batch-axis reduction in `{node.prim}` ({what}) "
                          f"with no clip contraction on any operand")

    def _absorb_sub(self, sub_pass: "TaintPass", body: FlatGraph):
        """Surface a sub-body's violations, dropping ones whose result is
        dead inside the body (e.g. the primal ``sum(losses)`` the capture
        backward traces but discards)."""
        live = body.backward_slice(
            [v for v in body.outvars if not isinstance(v, Literal)])
        body_ids = {id(n) for n in body.nodes}
        for v in sub_pass.violations:
            if id(v.node) in body_ids and not any(
                    not isinstance(ov, Literal) and ov in live
                    for ov in v.node.outvars):
                continue
            self.violations.append(v)
        self.approx.extend(sub_pass.approx)

    # -- per-node transfer -------------------------------------------------

    def _step(self, node: Node, taints: Dict[Var, Taint]):
        prim = node.prim
        ins = [_get(taints, v) for v in node.invars]
        out_shapes = [_shape(v) for v in node.outvars]

        handler = getattr(self, f"_h_{prim.replace('-', '_')}", None)
        if handler is not None:
            handler(node, ins, taints)
            return

        if not any(t.per_example or t.clipped or t.weight for t in ins):
            return  # untainted in, untainted out

        # Binary arithmetic broadcasts (rank-aligned), so a coefficient
        # shaped (B,1,...,1) against a (B,...) payload is still
        # elementwise for taint purposes — don't let the shape mismatch
        # drop it to the fallback, which would lose the weight flag.
        if prim in _MUL_LIKE or prim in _ADD_LIKE or prim == "select_n":
            self._elementwise(node, ins, taints)
            return

        # Generic same-shape rule: if every tainted operand has exactly
        # the output shape, the op is (for taint purposes) elementwise.
        if len(node.outvars) >= 1 and all(
                _shape(v) == out_shapes[0]
                for v, t in zip(node.invars, ins)
                if (t.per_example or t.clipped or t.weight)):
            self._elementwise(node, ins, taints)
            return

        self._fallback(node, ins, taints)

    def _elementwise(self, node: Node, ins: List[Taint], taints):
        batch = frozenset().union(*[t.batch for t in ins]) if ins else EMPTY
        pe = [t for t in ins if t.per_example]
        if node.prim in _MUL_LIKE:
            clipped = any(t.clipped or t.weight for t in ins) and bool(pe)
        elif node.prim == "select_n":
            # pred selects; the value operands carry the payload.
            vals = ins[1:]
            pev = [t for t in vals if t.per_example]
            clipped = bool(pev) and all(t.clipped or t.weight for t in pev)
        elif node.prim in _ADD_LIKE:
            clipped = bool(pe) and all(t.clipped or t.weight for t in pe)
        elif node.prim in _SHAPE_PASS and len(pe) == 1:
            clipped = pe[0].clipped
        else:
            clipped = bool(pe) and all(t.clipped or t.weight for t in pe)
        weight = bool(pe) and all(t.weight for t in pe)
        t = Taint(batch, clipped, weight)
        for ov in node.outvars:
            taints[ov] = t

    def _fallback(self, node: Node, ins: List[Taint], taints):
        """Unmodeled shape-changing primitive: if the output keeps a
        leading example axis, keep the taint there; otherwise treat it
        as a (possibly covered) batch reduction."""
        clipped = any(t.clipped for t in ins)
        weight = all(t.weight for t in ins if t.per_example) \
            and any(t.per_example for t in ins)
        payload = [t for t in ins if t.per_example and not t.weight]
        self.approx.append(node.prim)
        for ov in node.outvars:
            shp = _shape(ov)
            if shp and shp[0] == self.B and any(
                    0 in t.batch or self.B in
                    [(_shape(v)[a] if a < len(_shape(v)) else -1)
                     for a in t.batch]
                    for v, t in zip(node.invars, ins) if t.per_example):
                taints[ov] = Taint(frozenset({0}), clipped, weight)
            elif payload:
                self._reduce_event(node, ins, f"unmodeled `{node.prim}`")
                taints[ov] = Taint(EMPTY, self._covered(ins), False)
            else:
                taints[ov] = Taint(EMPTY, clipped, weight)

    # -- structured handlers ----------------------------------------------

    def _h_dp_tag(self, node: Node, ins, taints):
        t = ins[0]
        kind = node.params.get("kind")
        if kind in ("clip_coef",):
            # The structural clip recognition: downstream of this marker,
            # multiplying by the coefficients IS the clip contraction.
            t = dataclasses.replace(t, weight=True)
        taints[node.outvars[0]] = t

    def _h_broadcast_in_dim(self, node: Node, ins, taints):
        t = ins[0]
        bcd = node.params["broadcast_dimensions"]
        batch = frozenset(bcd[a] for a in t.batch if a < len(bcd))
        taints[node.outvars[0]] = dataclasses.replace(t, batch=batch)

    def _h_transpose(self, node: Node, ins, taints):
        t = ins[0]
        perm = node.params["permutation"]
        batch = frozenset(j for j, a in enumerate(perm) if a in t.batch)
        taints[node.outvars[0]] = dataclasses.replace(t, batch=batch)

    def _h_squeeze(self, node: Node, ins, taints):
        t = ins[0]
        dims = set(node.params["dimensions"])
        remap, j = {}, 0
        for a in range(len(_shape(node.invars[0]))):
            if a in dims:
                continue
            remap[a] = j
            j += 1
        batch = frozenset(remap[a] for a in t.batch if a in remap)
        taints[node.outvars[0]] = dataclasses.replace(t, batch=batch)

    def _h_reshape(self, node: Node, ins, taints):
        t = ins[0]
        in_shape = _shape(node.invars[0])
        out_shape = _shape(node.outvars[0])
        batch = set()
        for a in t.batch:
            split_all = (a < len(in_shape) and in_shape[a] == self.B)
            outs = _reshape_axis_map(in_shape, out_shape, a,
                                     split_all=split_all)
            batch.update(outs)
        taints[node.outvars[0]] = dataclasses.replace(
            t, batch=frozenset(batch))

    def _h_slice(self, node: Node, ins, taints):
        taints[node.outvars[0]] = ins[0]

    def _h_dynamic_slice(self, node: Node, ins, taints):
        taints[node.outvars[0]] = ins[0]

    def _h_dynamic_update_slice(self, node: Node, ins, taints):
        op, upd = ins[0], ins[1]
        taints[node.outvars[0]] = Taint(
            op.batch | upd.batch,
            (op.clipped or not op.per_example)
            and (upd.clipped or not upd.per_example)
            and (op.per_example or upd.per_example),
            op.weight and upd.weight)

    def _h_concatenate(self, node: Node, ins, taints):
        batch = frozenset().union(*[t.batch for t in ins])
        pe = [t for t in ins if t.per_example]
        clipped = bool(pe) and all(t.clipped or t.weight for t in pe)
        weight = bool(pe) and all(t.weight for t in pe)
        taints[node.outvars[0]] = Taint(batch, clipped, weight)

    def _h_pad(self, node: Node, ins, taints):
        taints[node.outvars[0]] = ins[0]

    def _h_sort(self, node: Node, ins, taints):
        for ov, t in zip(node.outvars, ins):
            taints[ov] = t

    def _h_iota(self, node: Node, ins, taints):
        taints[node.outvars[0]] = NONE

    def _h_reduce_sum(self, node: Node, ins, taints):
        self._reduce(node, ins, taints)

    def _h_reduce_max(self, node: Node, ins, taints):
        self._reduce(node, ins, taints)

    def _h_reduce_min(self, node: Node, ins, taints):
        self._reduce(node, ins, taints)

    def _h_reduce_prod(self, node: Node, ins, taints):
        self._reduce(node, ins, taints)

    def _h_reduce_and(self, node: Node, ins, taints):
        self._reduce(node, ins, taints)

    def _h_reduce_or(self, node: Node, ins, taints):
        self._reduce(node, ins, taints)

    def _h_argmax(self, node: Node, ins, taints):
        self._reduce(node, ins, taints)

    def _h_argmin(self, node: Node, ins, taints):
        self._reduce(node, ins, taints)

    def _reduce(self, node: Node, ins, taints):
        t = ins[0]
        axes = set(node.params.get("axes", ()))
        if t.batch & axes and t.per_example and not (t.clipped or t.weight):
            self._reduce_event(node, ins, "reduce over the example axis")
        remap, j = {}, 0
        for a in range(len(_shape(node.invars[0]))):
            if a in axes:
                continue
            remap[a] = j
            j += 1
        batch = frozenset(remap[a] for a in t.batch if a in remap)
        reduced_batch = bool(t.batch & axes)
        taints[node.outvars[0]] = Taint(
            batch,
            t.clipped or (reduced_batch and (t.clipped or t.weight)),
            t.weight and not reduced_batch)

    def _h_dot_general(self, node: Node, ins, taints):
        lhs_t, rhs_t = ins[0], ins[1]
        (lc, rc), (lb, rb) = node.params["dimension_numbers"]
        lhs_shape, rhs_shape = _shape(node.invars[0]), _shape(node.invars[1])
        covered = self._covered(ins)
        # A contracted (or dot-batch "diagonal"… no: dot batch dims are
        # elementwise) tainted axis is a batch reduction.
        for t, contract, label in ((lhs_t, lc, "lhs"), (rhs_t, rc, "rhs")):
            if t.per_example and (t.batch & set(contract)) \
                    and not (t.clipped or t.weight) and not covered:
                self._reduce_event(node, ins,
                                   f"dot_general contracts the {label} "
                                   f"example axis")
        # Output layout: [batch dims, lhs free, rhs free].
        out_batch = set()
        nb = len(lb)
        for i, (la, ra) in enumerate(zip(lb, rb)):
            if la in lhs_t.batch or ra in rhs_t.batch:
                out_batch.add(i)
        lhs_free = [a for a in range(len(lhs_shape))
                    if a not in lc and a not in lb]
        for i, a in enumerate(lhs_free):
            if a in lhs_t.batch:
                out_batch.add(nb + i)
        rhs_free = [a for a in range(len(rhs_shape))
                    if a not in rc and a not in rb]
        for i, a in enumerate(rhs_free):
            if a in rhs_t.batch:
                out_batch.add(nb + len(lhs_free) + i)
        pe = [t for t in ins if t.per_example]
        clipped = covered and bool(pe)
        weight = bool(pe) and all(t.weight for t in pe)
        taints[node.outvars[0]] = Taint(frozenset(out_batch), clipped,
                                        weight)

    def _h_conv_general_dilated(self, node: Node, ins, taints):
        lhs_t, rhs_t = ins[0], ins[1]
        dn = node.params["dimension_numbers"]
        lhs_spec, out_spec = dn.lhs_spec, dn.out_spec
        if not (lhs_t.per_example or rhs_t.per_example):
            return
        # Plain forward/data-grad conv: example axis in the conv-batch
        # position, kernel untainted — the example axis passes through.
        if lhs_t.batch == frozenset({lhs_spec[0]}) \
                and not rhs_t.per_example:
            taints[node.outvars[0]] = Taint(
                frozenset({out_spec[0]}), lhs_t.clipped, False)
            return
        # Per-example group trick (the paper's Algorithm 2): the example
        # axis indexes feature/batch *groups* (count divisible by B), so
        # each group sees exactly one example — the "contraction" stays
        # within-example and the output keeps the folded example axis on
        # its feature dim.  The standard AD weight gradient instead puts
        # B in the contracted input-feature position with a small group
        # count, which falls through to the reduction event below.
        fgc = node.params.get("feature_group_count", 1)
        bgc = node.params.get("batch_group_count", 1)
        rhs_spec = dn.rhs_spec
        grouped = ((lhs_t.batch == frozenset({lhs_spec[1]})
                    and fgc > 1 and fgc % self.B == 0)
                   or (lhs_t.batch == frozenset({lhs_spec[0]})
                       and bgc > 1 and bgc % self.B == 0))
        if grouped and rhs_t.batch == frozenset({rhs_spec[0]}):
            taints[node.outvars[0]] = Taint(
                frozenset({out_spec[1]}),
                lhs_t.clipped or rhs_t.clipped
                or lhs_t.weight or rhs_t.weight, False)
            return
        # Anything else (weight-gradient convs contract the example axis
        # through the feature/batch-group trick): a batch reduction.
        self._reduce_event(node, ins, "conv weight-gradient contraction")
        out_shape = _shape(node.outvars[0])
        covered = self._covered(ins)
        if out_shape and len(out_shape) > out_spec[0] \
                and out_shape[out_spec[0]] == self.B \
                and lhs_t.batch:
            taints[node.outvars[0]] = Taint(
                frozenset({out_spec[0]}), covered, False)
        else:
            taints[node.outvars[0]] = Taint(EMPTY, covered, False)

    def _h_gather(self, node: Node, ins, taints):
        # take_along_axis / indexing: per-example data or indices keep
        # the example axis when the output retains a leading B axis.
        op_t, idx_t = ins[0], ins[1]
        out_shape = _shape(node.outvars[0])
        pe = op_t.per_example or idx_t.per_example
        if not pe:
            return
        if out_shape and out_shape[0] == self.B:
            taints[node.outvars[0]] = Taint(
                frozenset({0}), op_t.clipped, op_t.weight)
        else:
            # A gather that drops the example axis only *selects*; no sum
            # happens, so it is not a reduction event — but the result is
            # cross-example-derived, so keep a conservative flag.
            taints[node.outvars[0]] = Taint(EMPTY, op_t.clipped, False)

    def _scatter_like(self, node: Node, ins, taints):
        op_t, upd_t = ins[0], ins[2] if len(ins) > 2 else ins[-1]
        out_shape = _shape(node.outvars[0])
        if not (op_t.per_example or upd_t.per_example):
            return
        if out_shape and out_shape[0] == self.B and upd_t.per_example:
            taints[node.outvars[0]] = Taint(
                frozenset({0}), upd_t.clipped, False)
            return
        # Updates accumulate into a non-example-indexed output: this is a
        # batch reduction (segment sums, embedding contribs).
        if upd_t.per_example and not (upd_t.clipped or upd_t.weight):
            self._reduce_event(node, [upd_t], "scatter-add over examples")
        taints[node.outvars[0]] = Taint(
            op_t.batch, upd_t.clipped or upd_t.weight, False)

    def _h_scatter_add(self, node: Node, ins, taints):
        self._scatter_like(node, ins, taints)

    def _h_scatter(self, node: Node, ins, taints):
        self._scatter_like(node, ins, taints)

    def _h_scatter_mul(self, node: Node, ins, taints):
        self._scatter_like(node, ins, taints)

    def _h_cumsum(self, node: Node, ins, taints):
        t = ins[0]
        ax = node.params.get("axis", 0)
        if ax in t.batch and not (t.clipped or t.weight):
            self._violate(node, "cumulative op runs *across* examples")
        taints[node.outvars[0]] = t

    def _h_cumlogsumexp(self, node: Node, ins, taints):
        self._h_cumsum(node, ins, taints)

    def _h_cummax(self, node: Node, ins, taints):
        self._h_cumsum(node, ins, taints)

    # -- control flow ------------------------------------------------------

    def _h_scan(self, node: Node, ins, taints):
        body = node.sub[0] if node.sub else None
        if body is None:
            self._fallback(node, ins, taints)
            return
        n_consts = node.params.get("num_consts", 0)
        n_carry = node.params.get("num_carry", 0)
        consts = ins[:n_consts]
        carry0 = ins[n_consts:n_consts + n_carry]
        xs = ins[n_consts + n_carry:]
        xs_scan_tainted = any(0 in t.batch for t in xs)

        carry_t = list(carry0)
        body_out = None
        for _ in range(8):  # carry fixpoint
            sub_init: Dict[Var, Taint] = {}
            body_iv = body.invars
            for v, t in zip(body_iv[:n_consts], consts):
                sub_init[v] = t
            for v, t in zip(body_iv[n_consts:n_consts + n_carry], carry_t):
                sub_init[v] = t
            for v, t in zip(body_iv[n_consts + n_carry:], xs):
                sub_init[v] = t.shift(-1)
            sub_pass = TaintPass(body, self.B)
            res = sub_pass.run(sub_init)
            body_out = [(_get(res.taints, v)
                         if not isinstance(v, Literal) else NONE)
                        for v in body.outvars]
            new_carry = body_out[:n_carry]
            if new_carry == carry_t:
                break
            carry_t = [Taint(a.batch | b.batch, a.clipped and b.clipped
                             if (a.per_example and b.per_example)
                             else (a.clipped or b.clipped),
                             a.weight and b.weight)
                       for a, b in zip(carry_t, new_carry)]
        # Surface body violations once (steady-state body).
        self._absorb_sub(sub_pass, body)

        ys = body_out[n_carry:]
        for ov, t in zip(node.outvars[:n_carry], carry_t):
            taints[ov] = t
        for ov, t in zip(node.outvars[n_carry:], ys):
            t2 = t.shift(+1)
            if xs_scan_tainted:
                t2 = dataclasses.replace(t2, batch=t2.batch | {0})
            taints[ov] = t2

    def _h_while(self, node: Node, ins, taints):
        body = node.sub[1] if node.sub and len(node.sub) > 1 else None
        if body is None:
            self._fallback(node, ins, taints)
            return
        cn = node.params.get("cond_nconsts", 0)
        bn = node.params.get("body_nconsts", 0)
        carry = ins[cn + bn:]
        carry_t = list(carry)
        for _ in range(8):
            sub_init = {}
            for v, t in zip(body.invars[:bn], ins[cn:cn + bn]):
                sub_init[v] = t
            for v, t in zip(body.invars[bn:], carry_t):
                sub_init[v] = t
            sub_pass = TaintPass(body, self.B)
            res = sub_pass.run(sub_init)
            new_carry = [(_get(res.taints, v)
                          if not isinstance(v, Literal) else NONE)
                         for v in body.outvars]
            if new_carry == carry_t:
                break
            carry_t = [Taint(a.batch | b.batch, a.clipped or b.clipped,
                             a.weight and b.weight)
                       for a, b in zip(carry_t, new_carry)]
        self._absorb_sub(sub_pass, body)
        for ov, t in zip(node.outvars, carry_t):
            taints[ov] = t

    def _h_cond(self, node: Node, ins, taints):
        if not node.sub:
            self._fallback(node, ins, taints)
            return
        args = ins[1:]  # operand 0 is the branch index
        outs = None
        for branch in node.sub:
            sub_init = dict(zip(branch.invars, args))
            sub_pass = TaintPass(branch, self.B)
            res = sub_pass.run(sub_init)
            self._absorb_sub(sub_pass, branch)
            bt = [(_get(res.taints, v)
                   if not isinstance(v, Literal) else NONE)
                  for v in branch.outvars]
            if outs is None:
                outs = bt
            else:
                outs = [Taint(a.batch | b.batch, a.clipped and b.clipped,
                              a.weight and b.weight)
                        for a, b in zip(outs, bt)]
        for ov, t in zip(node.outvars, outs or []):
            taints[ov] = t

    def _h_pallas_call(self, node: Node, ins, taints):
        self._fallback(node, ins, taints)


def _reshape_axis_map(in_shape, out_shape, axis,
                      split_all: bool = False) -> List[int]:
    """Output axes a tainted input axis lands on under a row-major
    reshape.  Merges taint the merged axis; splits taint only the
    outermost factor — the example axis stays the slowest-varying one in
    a flatten like (B·g,) → (B, g) — EXCEPT when the split axis is the
    example axis itself (``split_all``, the microbatch reshape
    (B,) → (m, B/m)): then every factor indexes examples and all split
    axes are tainted."""
    def spans(shape):
        out, period = [], int(np.prod(shape)) if shape else 1
        for d in shape:
            block = period // max(d, 1)
            out.append((block, period))
            period = block
        return out

    in_spans, out_spans = spans(in_shape), spans(out_shape)
    if axis >= len(in_spans):
        return []
    blk_i, per_i = in_spans[axis]
    hits = [j for j, (blk_j, per_j) in enumerate(out_spans)
            if not (per_j <= blk_i or blk_j >= per_i)]
    if len(hits) > 1:
        if split_all:
            return hits
        exact = [j for j in hits if out_spans[j] == in_spans[axis]]
        if exact:
            return exact[:1]
        return hits[:1]  # split: outermost factor only
    return hits
