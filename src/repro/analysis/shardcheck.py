"""Sharding-safety pass for the mesh-jitted private step.

The pipeline is written in the global view, so the traced jaxpr shows
no collectives — XLA inserts the psums when partitioning.  What *is*
statically checkable is the combination that forces SPMD to insert
them correctly:

  * the declared in/out shardings: batch split over the data axes on
    the leading (example) dim; the PRNG key strictly replicated; and
    params, optimizer state, clip state, and **every output**
    replicated *across the data axes* — partitioning over model axes
    is the tensor-parallel layout and is allowed, but any data-axis
    name in a param/opt/output spec is an error.  Data-replicated
    outputs are the load-bearing half: each shard of the clipped sum
    and the noised update must be bitwise-identical on every data
    replica, which XLA can only realize by all-reducing the per-shard
    partial sums (per-example Gram/norm contributions psum over
    ``model``, scalar norms over the data axes);
  * taint facts from the global graph: the clip decision (the
    ``clip_coef`` marker) is computed from all ``B`` global examples'
    norms — under a sharded batch that norm vector only exists after a
    psum, so "clip sees the global norm" is structural; and the noise
    markers carry **no** example axis — noise attaches to the
    aggregate, which the replicated-output constraint pins to one
    logical draw from the one replicated key, never independent
    per-shard draws (those would inflate the variance by the shard
    count and desynchronize the replicas).
"""
from __future__ import annotations

from typing import List, Optional

from repro.analysis.graph import FlatGraph
from repro.analysis.report import Finding

try:
    from jax.sharding import NamedSharding, PartitionSpec
except Exception:  # pragma: no cover - jax always present in this repo
    NamedSharding = PartitionSpec = None  # type: ignore

DATA_AXIS_NAMES = ("data", "pod", "batch", "dp", "fsdp")


def _is_replicated(sh) -> bool:
    spec = getattr(sh, "spec", sh)
    if spec is None:
        return True
    return all(p is None for p in tuple(spec))


def _data_replicated(sh) -> bool:
    """True iff no data axis appears in the spec — replicated across the
    data axes; model-axis partitioning (tensor parallelism) is fine."""
    spec = getattr(sh, "spec", sh)
    if spec is None:
        return True
    for p in tuple(spec):
        if p is None:
            continue
        axes = p if isinstance(p, (tuple, list)) else (p,)
        if any(ax in DATA_AXIS_NAMES for ax in axes):
            return False
    return True


def _leading_data_sharded(sh) -> bool:
    spec = tuple(getattr(sh, "spec", sh) or ())
    if not spec or spec[0] is None:
        return False
    first = spec[0] if isinstance(spec[0], (tuple, list)) else (spec[0],)
    return all(ax in DATA_AXIS_NAMES for ax in first) \
        and all(p is None for p in spec[1:])


def check_sharding(graph: FlatGraph, *, taints, batch_size: int,
                   mesh_axes: tuple, data_size: int,
                   in_shardings=None, out_shardings=None) -> List[Finding]:
    findings: List[Finding] = []
    where = "sharding"
    if not mesh_axes:
        return findings

    if data_size < 1 or batch_size % max(data_size, 1):
        findings.append(Finding(
            "error", "batch_not_divisible",
            f"global batch {batch_size} is not divisible by the mesh's "
            f"data-parallel degree {data_size}", where))

    # -- declared shardings ----------------------------------------------
    if in_shardings is not None:
        import jax
        names = ("params", "opt", "batch", "key", "clip_state")
        for name, tree in zip(names, in_shardings):
            leaves = jax.tree.leaves(tree)
            if name == "batch":
                bad = [s for s in leaves if not _leading_data_sharded(s)]
                if bad:
                    findings.append(Finding(
                        "error", "batch_not_sharded",
                        "a batch leaf is not sharded over the data axes "
                        "on its leading (example) dim — per-example work "
                        "would not be data-parallel", where))
            elif name == "key":
                bad = [s for s in leaves if not _is_replicated(s)]
                if bad:
                    findings.append(Finding(
                        "error", "key_sharded",
                        "key input is not replicated under the mesh — "
                        "per-shard key slices mean per-shard noise draws",
                        where))
            else:
                bad = [s for s in leaves if not _data_replicated(s)]
                if bad:
                    findings.append(Finding(
                        "error", f"{name}_not_replicated",
                        f"a {name} input is sharded over a data axis — "
                        f"params/opt/clip state must be replicated across "
                        f"the data shards (model-axis partitioning is the "
                        f"tensor-parallel layout and is allowed)", where))
    if out_shardings is not None:
        import jax
        bad = [s for s in jax.tree.leaves(out_shardings)
               if not _data_replicated(s)]
        if bad:
            findings.append(Finding(
                "error", "outputs_not_replicated",
                "a step output is sharded over a data axis — every shard "
                "of the clipped+noised update must be identical on every "
                "data replica (the all-reduce XLA inserts to realize that "
                "replication is what sums the per-shard contributions); "
                "model-axis partitioning is allowed", where))

    # -- taint facts on the global graph ----------------------------------
    for node, _ in graph.markers():
        kind = node.params.get("kind")
        if kind == "noise":
            t = taints.get(graph.resolve(node.invars[0])
                           if hasattr(graph, "resolve") else node.invars[0])
            if t is not None and t.batch:
                findings.append(Finding(
                    "error", "noise_per_example",
                    "a noise marker still carries the example axis — "
                    "noise must attach to the aggregate (one draw), not "
                    "to per-example/per-shard values", where))
        elif kind in ("clip_coef", "group_norm"):
            shape = tuple(getattr(node.outvars[0].aval, "shape", ()))
            if shape and batch_size not in shape:
                findings.append(Finding(
                    "error", "clip_not_global",
                    f"{kind} marker has shape {shape} — the clip decision "
                    f"does not cover all {batch_size} global examples "
                    f"(norms must be globally reduced before clipping)",
                    where))

    return findings
