"""Jaxpr flattening and slicing for the static DP verifier.

``jax.make_jaxpr`` on the private step yields a ClosedJaxpr whose
interesting structure hides inside nested call equations (``pjit``,
``custom_jvp_call``, ``remat``).  :func:`flatten` inlines those into one
topologically ordered node list with variables resolved across call
boundaries, so the analysis passes walk a single graph.  Control-flow
equations that genuinely execute their body differently (``scan``,
``while``, ``cond``, ``pallas_call``) are kept as single nodes but carry
their recursively flattened bodies in ``Node.sub`` — passes that need to
look inside (taint through a scan, marker/noise census) can.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Set, Tuple

try:  # jax >= 0.4.16
    from jax.extend.core import ClosedJaxpr, Literal, Var
except ImportError:  # pragma: no cover - older jax
    from jax.core import ClosedJaxpr, Literal, Var  # type: ignore

# Call-like primitives whose body is semantically "run once, in place":
# safe to inline into the parent graph.
INLINE_PRIMS = ("pjit", "closed_call", "core_call", "call",
                "custom_jvp_call", "custom_vjp_call",
                "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr",
                "remat", "remat2", "checkpoint")

# Control-flow primitives kept opaque (one node) but with flattened
# bodies attached for recursive passes.
SUBGRAPH_PRIMS = ("scan", "while", "cond", "pallas_call")


@dataclasses.dataclass
class Node:
    """One flattened equation: primitive name, alias-resolved inputs,
    raw outputs, static params, and (for control flow) flattened
    sub-bodies."""

    prim: str
    invars: List[Any]            # Var | Literal, resolved
    outvars: List[Var]
    params: Dict[str, Any]
    sub: Optional[List["FlatGraph"]] = None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Node({self.prim}, in={len(self.invars)}, "
                f"out={len(self.outvars)})")


def _closed_of(obj) -> Optional[ClosedJaxpr]:
    """Coerce a params entry to a ClosedJaxpr when possible."""
    if obj is None:
        return None
    if isinstance(obj, ClosedJaxpr):
        return obj
    if hasattr(obj, "eqns"):  # an open Jaxpr
        if getattr(obj, "constvars", ()):
            return None
        return ClosedJaxpr(obj, ())
    return None


def _inner_closed(eqn) -> Optional[ClosedJaxpr]:
    p = eqn.params
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        closed = _closed_of(p.get(key))
        if closed is not None:
            return closed
    return None


def _sub_bodies(eqn) -> List["FlatGraph"]:
    p = eqn.params
    bodies = []
    if eqn.primitive.name == "cond":
        for br in p.get("branches", ()):
            c = _closed_of(br)
            if c is not None:
                bodies.append(flatten(c))
        return bodies
    if eqn.primitive.name == "while":
        for key in ("cond_jaxpr", "body_jaxpr"):
            c = _closed_of(p.get(key))
            if c is not None:
                bodies.append(flatten(c))
        return bodies
    c = _inner_closed(eqn)
    if c is not None:
        bodies.append(flatten(c))
    return bodies


class FlatGraph:
    """The flattened view of one ClosedJaxpr."""

    def __init__(self, closed: ClosedJaxpr):
        self.closed = closed
        self.nodes: List[Node] = []
        self.invars: List[Var] = list(closed.jaxpr.invars)
        self.const_vars: Set[Var] = set()
        self._alias: Dict[Var, Any] = {}
        self._flatten_body(closed.jaxpr)
        self.outvars: List[Any] = [self.resolve(v)
                                   for v in closed.jaxpr.outvars]
        self.producer: Dict[Var, Node] = {}
        for node in self.nodes:
            for ov in node.outvars:
                self.producer[ov] = node

    # -- construction ------------------------------------------------------

    def resolve(self, v):
        """Follow cross-call aliases to the canonical producer var."""
        while isinstance(v, Var) and v in self._alias:
            v = self._alias[v]
        return v

    def _flatten_body(self, jaxpr):
        for cv in jaxpr.constvars:
            self.const_vars.add(cv)
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            inner = _inner_closed(eqn) if name in INLINE_PRIMS else None
            if inner is not None:
                n_in = len(inner.jaxpr.invars)
                # Call conventions put any extra (const-like) operands
                # first; bind the *trailing* invars positionally.
                args = list(eqn.invars)[-n_in:] if n_in else []
                for iv, ov in zip(inner.jaxpr.invars, args):
                    self._alias[iv] = self.resolve(ov)
                for cv in inner.jaxpr.constvars:
                    self.const_vars.add(cv)
                self._flatten_body(inner.jaxpr)
                for eo, io in zip(eqn.outvars, inner.jaxpr.outvars):
                    self._alias[eo] = self.resolve(io)
                continue
            sub = _sub_bodies(eqn) if name in SUBGRAPH_PRIMS else None
            self.nodes.append(Node(
                prim=name,
                invars=[self.resolve(v) for v in eqn.invars],
                outvars=list(eqn.outvars),
                params=dict(eqn.params),
                sub=sub or None))

    # -- queries -----------------------------------------------------------

    def iter_nodes(self, recursive: bool = False) -> Iterator[Node]:
        for node in self.nodes:
            yield node
            if recursive and node.sub:
                for g in node.sub:
                    yield from g.iter_nodes(recursive=True)

    def markers(self) -> List[Tuple[Node, "FlatGraph"]]:
        """All ``dp_tag`` nodes, recursively, with their owning graph."""
        out = []
        for node in self.nodes:
            if node.prim == "dp_tag":
                out.append((node, self))
            if node.sub:
                for g in node.sub:
                    out.extend(g.markers())
        return out

    def count_prim(self, name: str) -> int:
        """Occurrences of a primitive, recursively (scan bodies count
        once — the static census, not the dynamic trip count)."""
        return sum(1 for n in self.iter_nodes(recursive=True)
                   if n.prim == name)

    def backward_slice(self, targets) -> Set[Var]:
        """Every var that (transitively) feeds ``targets``.  Control-flow
        nodes are conservative: all inputs feed all outputs."""
        seen: Set[Var] = set()
        stack = [t for t in targets if isinstance(t, Var)]
        while stack:
            v = stack.pop()
            if v in seen:
                continue
            seen.add(v)
            node = self.producer.get(v)
            if node is None:
                continue
            for iv in node.invars:
                if isinstance(iv, Var) and iv not in seen:
                    stack.append(iv)
        return seen


def flatten(closed: ClosedJaxpr) -> FlatGraph:
    return FlatGraph(closed)


def aval_of(v):
    """The abstract value of a Var or Literal."""
    return v.aval
