"""Plan/graph consistency: the ExecPlan's declared realizations must
actually appear in the traced step.

The planner publishes per-layer decisions (``LayerPlan.norm_method``,
``stash``, ``fused``; ``GroupPlan.norm_mode``/``sum_method``); the
executing pipeline tags what it *really* ran (``dp_tag`` markers of
kind ``group_norm``, ``realization``, ``fused_impl``).  A silent
divergence — a stale deserialized plan, a dispatch bug, a refactor that
stopped honoring the plan — would make ``engine.explain()`` and the
cost model describe a step that never executes.  This pass
cross-checks, per parameter group:

  * a ``group_norm`` marker exists with the planned method
    (``stash`` / the layer's norm method / ``tied`` / ``pe``);
  * layers whose norm the plan realizes analytically carry a matching
    ``realization`` marker at the layer's parameter path;
  * stale-fused layers carry a ``fused_impl`` marker, and the
    ``tapper.STATS`` deltas recorded while tracing agree (exactly one
    forward/backward plus the planned extra weighted backward, zero
    probes once planned, fused counter live iff the plan fused);
  * the plan's fingerprint matches the engine's live fingerprint (with
    the model-code hash folded in, a plan-store entry from different
    sources fails here);
  * predicted per-device collective bytes over a threshold raise a
    *warning* — surfacing layouts like the 7x ``alexnet@data:8``
    stash-traffic regression at verify time instead of bench time.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.analysis.graph import FlatGraph
from repro.analysis.report import Finding

# Predicted per-device collective traffic per step above which dpcheck
# warns (64 MB/device/step; the known-bad alexnet@data:8 layout predicts
# ~135 MB/device/step).
COLL_BYTES_WARN = 64 * 2**20


def _expected_group_method(g, plan, stale_steady: bool) -> List[str]:
    """Acceptable ``group_norm`` marker methods for one plan group."""
    if g.norm_mode == "tied":
        return ["tied"]
    if g.norm_mode == "group_pe":
        return ["pe"]
    lp = plan.layers[g.members[0]]
    if stale_steady and lp.fused:
        return [lp.norm_method]
    if lp.stash:
        return ["stash"]
    return [lp.norm_method]


def check_plan(graph: FlatGraph, *, plan, clip_mode: str,
               stale_steady: bool, stats_delta: Optional[Dict[str, int]],
               expected_fingerprint: Optional[str] = None,
               coll_bytes_warn: float = COLL_BYTES_WARN) -> List[Finding]:
    findings: List[Finding] = []
    where = "plan"
    if plan is None:
        return findings

    by_kind: Dict[str, list] = {}
    for node, _ in graph.markers():
        by_kind.setdefault(node.params.get("kind", "?"), []).append(node)

    group_markers = {}
    for node in by_kind.get("group_norm", []):
        group_markers.setdefault(node.params.get("group"), []).append(
            node.params)
    realization_paths = {}
    for kind in ("realization", "fused_impl"):
        for node in by_kind.get(kind, []):
            realization_paths.setdefault(
                (kind, node.params.get("path")), []).append(
                node.params.get("method"))

    from repro.core.strategies import group_key_of

    for g in plan.groups:
        key = group_key_of(g.path)
        expect = _expected_group_method(g, plan, stale_steady)
        seen = group_markers.get(key, [])
        if not seen:
            findings.append(Finding(
                "error", "plan_group_missing",
                f"plan group {key!r} ({g.norm_mode}/{g.sum_method}) has no "
                f"group_norm marker in the traced step — its planned "
                f"realization never executed", where))
            continue
        methods = {m.get("method") for m in seen}
        if not methods & set(expect):
            findings.append(Finding(
                "error", "plan_method_mismatch",
                f"plan group {key!r} declares norm method {expect} but the "
                f"step realized {sorted(methods)}", where))
        if stale_steady:
            lp = plan.layers[g.members[0]]
            if g.norm_mode == "single" and lp.fused \
                    and not any(m.get("fused") for m in seen):
                findings.append(Finding(
                    "error", "plan_fused_missing",
                    f"plan marks group {key!r} fused (single-pass "
                    f"norm+contrib) but the step ran the two-reduction "
                    f"path", where))
        # Analytic single-layer realizations must also be visible at the
        # kind level (the census `apply_kind` actually dispatched).
        if g.norm_mode == "single":
            lp = plan.layers[g.members[0]]
            if stale_steady and lp.fused:
                if ("fused_impl", key) not in realization_paths:
                    findings.append(Finding(
                        "error", "plan_fused_missing",
                        f"no fused_impl marker for fused group {key!r}",
                        where))
            elif not lp.stash \
                    and ("realization", key) not in realization_paths:
                findings.append(Finding(
                    "error", "plan_realization_missing",
                    f"no realization marker at path {key!r} for planned "
                    f"norm method {lp.norm_method!r}", where))

    # -- STATS census ------------------------------------------------------
    if stats_delta is not None:
        expect_bwd = 1 + (1 if (plan.needs_backward and not stale_steady)
                          else 0)
        for field in ("forwards", "backwards"):
            got = stats_delta.get(field, -1)
            if got != expect_bwd:
                findings.append(Finding(
                    "error", "stats_mismatch",
                    f"traced {got} {field} but the plan promises "
                    f"{expect_bwd} (needs_backward={plan.needs_backward})",
                    where))
        if stats_delta.get("probes", 0) != 0:
            findings.append(Finding(
                "warning", "stats_probe",
                f"{stats_delta['probes']} shape probe(s) ran during the "
                f"traced step — planned execution should never re-probe",
                where))
        any_fused = any(lp.fused for lp in plan.layers.values())
        fused_runs = stats_delta.get("fused", 0)
        if stale_steady and any_fused and fused_runs == 0:
            findings.append(Finding(
                "error", "plan_fused_missing",
                "plan has fused layers but no fused norm+contrib pass "
                "executed (STATS.fused did not move)", where))
        if fused_runs > 0 and not (stale_steady and any_fused):
            findings.append(Finding(
                "warning", "stats_fused_unplanned",
                f"{fused_runs} fused norm+contrib pass(es) executed but "
                f"the plan declares none", where))

    # -- identity ---------------------------------------------------------
    if expected_fingerprint is not None \
            and plan.fingerprint and plan.fingerprint != expected_fingerprint:
        findings.append(Finding(
            "error", "plan_fingerprint_stale",
            f"executing plan fingerprint {plan.fingerprint} != the "
            f"engine's live fingerprint {expected_fingerprint} — stale "
            f"plan-store entry (model code or shapes changed)", where))
    if plan.clip_mode != clip_mode:
        findings.append(Finding(
            "error", "plan_clip_mode_mismatch",
            f"plan was built for clipping mode {plan.clip_mode!r}, the "
            f"engine clips {clip_mode!r}", where))

    # -- predicted collective traffic -------------------------------------
    if coll_bytes_warn and plan.total_coll_bytes > coll_bytes_warn:
        by_axis = getattr(plan, "total_coll_bytes_by_axis", ())
        per_axis = ("" if not by_axis else " ["
                    + ", ".join(f"{a}: {b / 2**20:.1f} MB"
                                for a, b in by_axis) + "]")
        findings.append(Finding(
            "warning", "coll_bytes_high",
            f"plan predicts {plan.total_coll_bytes / 2**20:.1f} MB/device "
            f"of collective traffic per step{per_axis} (threshold "
            f"{coll_bytes_warn / 2**20:.0f} MB) — a stash/backward layout "
            f"is putting per-example state on the wire; compare "
            f"realizations with engine.explain()", where))

    return findings
