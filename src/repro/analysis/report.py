"""Structured findings for the static DP verifier."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verifier observation.

    ``severity``: "error" (a DP invariant is broken or unprovable),
    "warning" (legal but suspicious — e.g. pathological predicted
    collective traffic), or "info" (context only; never fails a gate).
    ``code`` is a stable machine-readable slug (what the mutation suite
    asserts on); ``where`` names the pass and, when known, the graph
    location.
    """

    severity: str
    code: str
    message: str
    where: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.severity.upper():7s} {self.code}: {self.message}{loc}"


@dataclasses.dataclass
class VerifyReport:
    """The result of :func:`repro.analysis.verifier.verify_engine`.

    ``target`` describes the verified engine (model / clip mode / mesh);
    ``checked`` maps each pass name to a one-line summary of what it
    established (shown even when everything is clean, so a passing
    report documents *what* was proven, not just the absence of
    findings).
    """

    target: str
    findings: List[Finding] = dataclasses.field(default_factory=list)
    checked: Dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def has(self, code: str) -> bool:
        return any(f.code == code for f in self.findings)

    def raise_if_failed(self):
        if not self.ok:
            raise DPVerificationError(self)

    def summary(self) -> str:
        head = "PASS" if self.ok else "FAIL"
        lines = [f"[{head}] dpcheck: {self.target} — "
                 f"{len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s)"]
        for name, what in self.checked.items():
            lines.append(f"  ✓ {name}: {what}")
        for f in self.findings:
            if f.severity != "info":
                lines.append(f"  {f}")
        return "\n".join(lines)


class DPVerificationError(AssertionError):
    """Raised by ``VerifyReport.raise_if_failed`` when errors exist."""

    def __init__(self, report: VerifyReport):
        self.report = report
        super().__init__(report.summary())
