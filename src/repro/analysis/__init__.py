"""Static DP verification (``dpcheck``).

Traces :class:`repro.core.engine.PrivacyEngine`'s private step to a
jaxpr and proves the clip → aggregate → noise pipeline is well-formed
by abstract interpretation — no execution.  Entry points:

  * ``engine.verify()`` — the engine-side surface (returns a
    :class:`~repro.analysis.report.VerifyReport`);
  * :func:`repro.analysis.verifier.verify_engine` — the functional core;
  * ``python -m repro.launch.dpcheck`` — the CLI sweep over the model
    registry × clip modes × mesh specs (the CI gate).

The pipeline cooperates by tagging its load-bearing values with the
zero-cost :func:`repro.analysis.markers.tag` primitive (clip
coefficients, group norms, realizations, noise terms), so the analyzer
recognizes structure instead of pattern-matching primitive soup.
"""
from repro.analysis.markers import MARKER_PRIMITIVE, is_marker, tag
from repro.analysis.report import (DPVerificationError, Finding,
                                   VerifyReport)
from repro.analysis.verifier import verify_engine

__all__ = [
    "DPVerificationError",
    "Finding",
    "MARKER_PRIMITIVE",
    "VerifyReport",
    "is_marker",
    "tag",
    "verify_engine",
]
