"""Differentially-private CNN training (the paper's application).

Trains a small CNN on synthetic class-conditional images with DP-SGD
through the plan-first PrivacyEngine: make private once, then every step
is plan -> private_step -> account.  The crb reconstruction of the paper
is pinned via ``DPConfig(strategy="crb")``.

    PYTHONPATH=src python examples/dp_train_cnn.py --steps 60
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import DPConfig, PrivacyEngine
from repro.data import SyntheticImageDataset, poisson_batch_indices
from repro.models.registry import build_model
from repro.optim import sgdm_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--noise", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--strategy", default="crb",
                    choices=["naive", "multi", "crb", "ghost", "bk", "auto"])
    args = ap.parse_args()

    cfg = get_config("alexnet").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = sgdm_init(params)
    ds = SyntheticImageDataset(cfg.img_size, cfg.n_classes, n_examples=4096)
    idx0, _ = poisson_batch_indices(0, len(ds), args.batch / len(ds),
                                    args.batch)
    engine = PrivacyEngine(
        model.apply, params, ds.batch(idx0),
        dp=DPConfig(l2_clip=args.clip, noise_multiplier=args.noise,
                    strategy=args.strategy),
        optimizer="sgdm", lr=args.lr, sampling_rate=args.batch / len(ds))
    print(engine.explain())

    for s in range(args.steps):
        idx, mask = poisson_batch_indices(s, len(ds), args.batch / len(ds),
                                          args.batch)
        batch = jax.tree.map(jnp.asarray, ds.batch(idx))
        params, opt, loss, aux = engine.private_step(
            params, opt, batch, jax.random.PRNGKey(100 + s))
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:3d}  loss {float(loss):.4f}  "
                  f"clip_frac {float(aux['clip_fraction']):.2f}  "
                  f"{engine.report()}")


if __name__ == "__main__":
    main()
