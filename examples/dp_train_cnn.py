"""Differentially-private CNN training (the paper's application).

Trains a small CNN on synthetic class-conditional images with DP-SGD using
the paper's crb strategy, reporting the privacy budget as it composes.

    PYTHONPATH=src python examples/dp_train_cnn.py --steps 60
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import DPConfig, PrivacyAccountant
from repro.core.clipping import dp_gradient
from repro.data import SyntheticImageDataset, poisson_batch_indices
from repro.models.registry import build_model
from repro.optim import sgdm_init, sgdm_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--noise", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    cfg = get_config("alexnet").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = sgdm_init(params)
    ds = SyntheticImageDataset(cfg.img_size, cfg.n_classes, n_examples=4096)
    dpc = DPConfig(l2_clip=args.clip, noise_multiplier=args.noise,
                   strategy="crb")
    acct = PrivacyAccountant(sampling_rate=args.batch / len(ds),
                             noise_multiplier=args.noise)

    @jax.jit
    def step(params, opt, batch, key):
        loss, grad, aux = dp_gradient(model.apply, params, batch, cfg=dpc,
                                      key=key, denom=args.batch)
        params, opt = sgdm_update(grad, opt, params, lr=args.lr)
        return params, opt, loss, aux["clip_fraction"]

    for s in range(args.steps):
        idx, mask = poisson_batch_indices(s, len(ds), args.batch / len(ds),
                                          args.batch)
        batch = jax.tree.map(jnp.asarray, ds.batch(idx))
        params, opt, loss, cf = step(params, opt, batch,
                                     jax.random.PRNGKey(100 + s))
        acct.step()
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:3d}  loss {float(loss):.4f}  "
                  f"clip_frac {float(cf):.2f}  {acct.report()}")


if __name__ == "__main__":
    main()
