"""Per-example gradient norms beyond DP: data attribution.

The paper's introduction motivates per-example gradients for "a quantity
of interest unique to each example" — e.g. importance sampling (Alain et
al. 2015) or data debugging.  Here: plant label noise in a synthetic
image dataset and show that ghost norms (computed *without materializing
any per-example gradient*) separate corrupted from clean examples.

    PYTHONPATH=src python examples/grad_attribution.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ghost_norms
from repro.core.clipping import non_dp_gradient
from repro.data import SyntheticImageDataset
from repro.models.registry import build_model
from repro.optim import sgdm_init, sgdm_update

rng = np.random.RandomState(0)
cfg = get_config("alexnet").reduced()
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
ds = SyntheticImageDataset(cfg.img_size, cfg.n_classes, n_examples=512)

# quick warm-up training so gradients reflect the data distribution
opt = sgdm_init(params)
step = jax.jit(lambda p, o, b: sgdm_update(
    non_dp_gradient(model.apply, p, b)[1], o, p, lr=0.05))
for s in range(30):
    idx = (np.arange(16) + s * 16) % len(ds)
    batch = jax.tree.map(jnp.asarray, ds.batch(idx))
    params, opt = step(params, opt, batch)

# build an eval batch with 25% corrupted labels
B = 32
batch = ds.batch(np.arange(B))
corrupt = rng.choice(B, B // 4, replace=False)
labels = np.array(batch["label"])
labels[corrupt] = (labels[corrupt] + 1 + rng.randint(0, cfg.n_classes - 1,
                                                     len(corrupt))) \
    % cfg.n_classes
batch = {"img": jnp.asarray(batch["img"]), "label": jnp.asarray(labels)}

_, norms_sq, _ = ghost_norms(model.apply, params, batch)
norms = np.sqrt(np.asarray(norms_sq))
is_bad = np.zeros(B, bool)
is_bad[corrupt] = True
print(f"mean grad-norm clean:     {norms[~is_bad].mean():8.3f}")
print(f"mean grad-norm corrupted: {norms[is_bad].mean():8.3f}")

# rank by norm: how many of the top-|corrupt| are actually corrupted?
top = np.argsort(-norms)[: len(corrupt)]
hits = np.intersect1d(top, corrupt).size
print(f"label-noise detection: {hits}/{len(corrupt)} corrupted examples "
      f"in the top-{len(corrupt)} gradient norms "
      f"(chance ≈ {len(corrupt)**2 / B:.1f})")
assert norms[is_bad].mean() > norms[~is_bad].mean(), "no separation?!"
print("OK")
