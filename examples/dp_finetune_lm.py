"""End-to-end driver: DP training of a transformer LM with ghost clipping,
checkpointing, fault tolerance and privacy accounting — the production
workflow at laptop scale.  ``--d-model 640 --layers 12`` gives a ~100M
model (hours on this CPU; the default is a quick demonstration).

    PYTHONPATH=src python examples/dp_finetune_lm.py --steps 120
    PYTHONPATH=src python examples/dp_finetune_lm.py \
        --d-model 640 --layers 12 --steps 300        # ~100M params
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--noise", type=float, default=0.6)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    argv = ["--arch", "llama3.2-1b", "--steps", str(args.steps),
            "--batch", "16", "--seq", "128", "--lr", "3e-3",
            "--clip", "1.0", "--noise", str(args.noise),
            "--strategy", "bk", "--microbatches", "2",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25"]
    if args.d_model:
        argv += ["--d-model", str(args.d_model)]
    if args.layers:
        argv += ["--layers", str(args.layers)]
    losses = train_mod.main(argv)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
