"""Quickstart: per-example gradients five ways on a small CNN.

Reproduces the paper's core claim in ~40 lines of user code: the
chain-rule-based reconstruction (crb, Algorithms 1-2) produces *exactly*
the per-example gradients of the naive batch-size-1 loop, and the ghost /
book-keeping extensions produce exactly the same *clipped* DP gradient.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import clipped_grad_sum, ghost_norms, per_example_grads
from repro.core.tapper import Tapper

rng = np.random.RandomState(0)
B = 8


def apply_fn(params, batch, tp: Tapper):
    """Tiny CNN: conv -> relu -> conv -> relu -> GAP -> linear."""
    h = tp.conv("c1", batch["img"], params["c1"]["w"], params["c1"]["b"],
                stride=1, padding=1)
    h = jax.nn.relu(h)
    h = tp.conv("c2", h, params["c2"]["w"], params["c2"]["b"], stride=2)
    h = jax.nn.relu(h).mean(axis=(2, 3))
    logits = tp.dense("fc", h, params["fc"]["w"], params["fc"]["b"])
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["label"][:, None], 1)[:, 0]


params = {
    "c1": {"w": jnp.array(rng.randn(8, 3, 3, 3) * 0.2, jnp.float32),
           "b": jnp.zeros(8)},
    "c2": {"w": jnp.array(rng.randn(16, 8, 3, 3) * 0.2, jnp.float32),
           "b": jnp.zeros(16)},
    "fc": {"w": jnp.array(rng.randn(16, 10) * 0.3, jnp.float32),
           "b": jnp.zeros(10)},
}
batch = {"img": jnp.array(rng.randn(B, 3, 16, 16), jnp.float32),
         "label": jnp.array(rng.randint(0, 10, (B,)))}

print("== per-example gradients ==")
_, pe_naive = per_example_grads(apply_fn, params, batch, "naive")
for s in ("multi", "crb"):
    _, pe = per_example_grads(apply_fn, params, batch, s)
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(pe), jax.tree.leaves(pe_naive)))
    print(f"  {s:6s} vs naive: max diff {err:.2e}")

print("== ghost norms (no materialization) ==")
true_sq = sum(jnp.sum(g.reshape(B, -1) ** 2, 1)
              for g in jax.tree.leaves(pe_naive))
_, norms_sq, _ = ghost_norms(apply_fn, params, batch)
print(f"  max rel err vs true: "
      f"{float(jnp.abs(norms_sq / true_sq - 1).max()):.2e}")

print("== DP-clipped gradient sums ==")
C = 0.1
_, ref, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                             strategy="naive")
for s in ("crb", "ghost", "bk", "auto"):
    _, g, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                               strategy=s)
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(g), jax.tree.leaves(ref)))
    print(f"  {s:6s} vs naive: max diff {err:.2e}")
print("OK")
