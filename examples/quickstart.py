"""Quickstart: plan-first DP-SGD on a small CNN with PrivacyEngine.

Make private once, step many: the engine plans a per-layer execution
strategy (the paper's chain-rule reconstruction vs ghost norms vs
materialization, chosen per layer by the cost model), then every training
step is one jitted closure over that plan — exactly one forward and one
backward.  The plan is a first-class value: inspect it with
``engine.explain()``, serialize it with ``plan.to_json()``, and verify
below that the legacy strategy zoo (naive / multi / crb / ghost / bk)
produces the same clipped gradient.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import DPConfig, ExecPlan, PrivacyEngine
from repro.core import clipped_grad_sum
from repro.core.tapper import STATS, Tapper
from repro.optim import adamw_init

rng = np.random.RandomState(0)
B = 8


def apply_fn(params, batch, tp: Tapper):
    """Tiny CNN: conv -> relu -> conv -> relu -> GAP -> linear."""
    h = tp.conv("c1", batch["img"], params["c1"]["w"], params["c1"]["b"],
                stride=1, padding=1)
    h = jax.nn.relu(h)
    h = tp.conv("c2", h, params["c2"]["w"], params["c2"]["b"], stride=2)
    h = jax.nn.relu(h).mean(axis=(2, 3))
    logits = tp.dense("fc", h, params["fc"]["w"], params["fc"]["b"])
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, batch["label"][:, None], 1)[:, 0]


params = {
    "c1": {"w": jnp.array(rng.randn(8, 3, 3, 3) * 0.2, jnp.float32),
           "b": jnp.zeros(8)},
    "c2": {"w": jnp.array(rng.randn(16, 8, 3, 3) * 0.2, jnp.float32),
           "b": jnp.zeros(16)},
    "fc": {"w": jnp.array(rng.randn(16, 10) * 0.3, jnp.float32),
           "b": jnp.zeros(10)},
}
batch = {"img": jnp.array(rng.randn(B, 3, 16, 16), jnp.float32),
         "label": jnp.array(rng.randint(0, 10, (B,)))}

C = 0.1
engine = PrivacyEngine(apply_fn, params, batch,
                       dp=DPConfig(l2_clip=C, noise_multiplier=0.8),
                       sampling_rate=B / 4096, lr=0.05)

print("== the plan (engine.explain) ==")
print(engine.explain())

print("\n== planned gradient vs the strategy zoo ==")
# A noise-free twin of the engine (same plan) for exact comparisons: the
# engine refuses to silently skip noise when noise_multiplier > 0.
quiet = PrivacyEngine(apply_fn, params, batch, dp=DPConfig(l2_clip=C))
STATS.reset()
_, grad, aux = quiet.noisy_grad(params, batch)
snap = STATS.snapshot()
assert (snap["forwards"], snap["backwards"]) == (1, 1), snap
print(f"  engine: 1 forward + 1 backward "
      f"(clip_frac {float(aux['clip_fraction']):.2f})")
gsum_engine = jax.tree.map(lambda g: g * B, grad)   # undo the mean
for s in ("naive", "multi", "crb", "ghost", "bk"):
    _, gsum, _ = clipped_grad_sum(apply_fn, params, batch, l2_clip=C,
                                  strategy=s)
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(gsum), jax.tree.leaves(gsum_engine)))
    print(f"  {s:6s} vs engine: max diff {err:.2e}")

print("\n== plan serialization round trip ==")
plan = engine.plan()
restored = ExecPlan.from_json(plan.to_json())
assert restored == plan
engine2 = PrivacyEngine(apply_fn, params, batch, dp=DPConfig(l2_clip=C),
                        plan=restored)
_, grad2, _ = engine2.noisy_grad(params, batch)
err = max(float(jnp.abs(a - b).max()) for a, b in
          zip(jax.tree.leaves(grad2), jax.tree.leaves(grad)))
print(f"  from_json(to_json(plan)) == plan; grads via restored plan "
      f"max diff {err:.2e}")

print("\n== a few private steps (jitted, accounted) ==")
opt = adamw_init(params)
p = params
for step in range(3):
    p, opt, loss, aux = engine.private_step(p, opt, batch,
                                            jax.random.PRNGKey(step))
    print(f"  step {step} loss {float(loss):.4f} "
          f"clip_frac {float(aux['clip_fraction']):.2f}  [{engine.report()}]")
print("OK")
