"""Batched LM serving example: prefill + KV-cache decode over a request
queue (the laptop twin of the decode_32k dry-run cells).

    PYTHONPATH=src python examples/serve_lm.py --arch glm4-9b
"""
import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()
    serve_mod.main(["--arch", args.arch, "--n-requests",
                    str(args.n_requests), "--batch", "4",
                    "--prompt-len", "16", "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
